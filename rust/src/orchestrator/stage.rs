//! One stage's serving thread: engine construction, input routing
//! (frontend requests + upstream items through transfers), the
//! scheduler-driven engine loop, and output forwarding.
//!
//! Inputs no longer drain straight into the engine: every submission goes
//! through a [`StageScheduler`] whose [`crate::scheduler::BatchPolicy`]
//! decides, at each token boundary, what joins the engine's batch
//! (paper §3.3 per-stage request batching).
//!
//! The loop body runs under [`crate::event_core::drive`]: when an
//! iteration finds no work, the thread parks on the replica's
//! [`WakeSet`] until an edge push/close, frontend submission, cancel
//! mark, or control command wakes it — no spin-polling.  The same body
//! shape (a closure returning [`Tick`]) is what `scheduler::sim` drives
//! under a virtual clock.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use super::{RunClock, StageSummary};
use crate::config::{CacheConfig, StageConfig, StageKind};
use crate::connector::router::{RouterRx, RouterTx};
use crate::connector::TryRecv;
use crate::engine::ar::{ArEngine, ArEngineOptions, ArJob, Preprocess, PromptItem};
use crate::engine::diffusion::{DiffusionEngine, DiffusionOptions};
use crate::engine::encoder::{EncodeJob, EncoderEngine};
use crate::engine::vocoder::{VocoderEngine, VocoderKind};
use crate::engine::{SamplingParams, StageItem};
use crate::event_core::{drive, RealDriver, Tick, WakeSet, WAKE_SINK};
use crate::metrics::{Event, Recorder};
use crate::runtime::{Artifacts, HostTensor, StageRuntime};
use crate::scheduler::{EngineView, StageAssignment, StageScheduler};
use crate::stage_graph::transfers::{EngineCmd, ReqTable, Registry, Transfer, TransferCtx};
use crate::trace::Request;
use crate::util::Prng;

/// Engine-occupancy samples are recorded every this many loop iterations
/// (plus whenever the scheduler admits something), keeping the recorder's
/// lock cold on the hot path.
const SAMPLE_EVERY: u64 = 32;

/// Session hook invoked as `(req_id, stage, t)` when a stage finishes
/// producing for a request — feeds `OutputDelta::StageDone` markers
/// into the request's [`crate::serving::ResponseStream`].
pub type StageDoneHook = Arc<dyn Fn(u64, &'static str, f64) + Send + Sync>;

pub struct StageSpec {
    pub index: usize,
    /// Which engine replica of the stage this thread serves (0-based;
    /// always 0 for unreplicated stages).
    pub replica: usize,
    pub cfg: StageConfig,
    pub artifacts: Arc<Artifacts>,
    /// Incoming edges: fan-in router receiver + transfer name.
    pub rxs: Vec<(RouterRx, String)>,
    /// Outgoing edges (items are cloned per edge; each router picks the
    /// consumer replica).
    pub txs: Vec<RouterTx>,
    pub registry: Registry,
    pub reqs: ReqTable,
    pub recorder: Arc<Recorder>,
    pub clock: RunClock,
    pub stop: Arc<std::sync::atomic::AtomicBool>,
    /// Per-replica retire signal (elastic scale-down): once set — after
    /// the control plane has drained this replica's incoming edges — the
    /// thread exits as soon as its engine and admission queue are empty,
    /// leaving the rest of the pipeline running.
    pub retire: Arc<std::sync::atomic::AtomicBool>,
    /// Live load published for the autoscaler (admission-queue depth +
    /// engine busyness), updated every loop iteration.
    pub slot: Arc<crate::serving::ReplicaSlot>,
    /// Set when any stage replica thread fails, so the orchestrator's
    /// collector loop stops waiting for completions that will never
    /// arrive (the failed thread's error surfaces at join time).
    pub failed: Arc<std::sync::atomic::AtomicBool>,
    /// Resolved scheduling assignment (policy, budgets, devices) from the
    /// orchestrator's [`crate::scheduler::AllocationPlan`].
    pub assignment: StageAssignment,
    /// Entry stage only: frontend request channel.
    pub front_rx: Option<mpsc::Receiver<Request>>,
    /// Exit stage only: completed-item sink.
    pub sink: Option<mpsc::Sender<StageItem>>,
    /// Fractional GPU sharing: the replica's slot on its device's
    /// time-slice scheduler.  When set, every engine step runs under an
    /// exclusive [`crate::gpu_share::StepGrant`], so co-resident slots
    /// interleave at step boundaries (`None` = whole device, no
    /// slicing).
    pub share: Option<(Arc<crate::gpu_share::TimeSlice>, crate::gpu_share::SlotId)>,
    /// Cancelled-request tombstones (end-to-end cancellation): items of
    /// tombstoned requests are dropped at every pull, and on each
    /// generation change the loop sweeps its admission queue and engine.
    pub cancels: Arc<crate::serving::Tombstones>,
    /// Stage-finished notification for the streaming API (None in
    /// engine-level tests).
    pub on_stage_done: Option<StageDoneHook>,
    pub streaming: bool,
    pub lazy_compile: bool,
    /// Cross-request caching knobs (prefix cache, eviction policy,
    /// encoder-output cache capacity).
    pub cache: CacheConfig,
    /// Per-device memory budget (KV sizing).
    pub device_bytes: usize,
    /// Per-tenant WFQ weights for the stage's admission queue, indexed
    /// by interned tenant id (empty = every tenant weighs 1.0).
    pub tenant_weights: Vec<f64>,
    /// Transfer context template for incoming edges (chunk sizes etc.).
    pub downstream_hint: TransferCtx,
    /// Rendezvous after engine construction (compilation excluded from
    /// request timing).
    pub ready: Arc<std::sync::Barrier>,
    /// The replica's wake mailbox (event core): edge pushes and closes,
    /// frontend submissions, cancel tombstones, and control commands all
    /// wake the thread, so an idle iteration parks instead of polling.
    pub wake: Arc<WakeSet>,
    /// Exit stage only: the session collector's wake mailbox, signalled
    /// after every sink send so the collector never sleeps on a full
    /// channel.
    pub sink_wake: Option<Arc<WakeSet>>,
}

enum Engine {
    Ar(Box<ArEngine>),
    Diffusion(Box<DiffusionEngine>),
    Vocoder(Box<VocoderEngine>),
    Encoder(Box<EncoderEngine>),
}

impl Engine {
    fn idle(&self) -> bool {
        match self {
            Engine::Ar(e) => e.idle(),
            Engine::Diffusion(e) => e.idle(),
            Engine::Vocoder(e) => e.idle(),
            Engine::Encoder(e) => e.idle(),
        }
    }

    fn step(&mut self) -> Result<Vec<StageItem>> {
        match self {
            Engine::Ar(e) => e.step(),
            Engine::Diffusion(e) => e.step(),
            Engine::Vocoder(e) => e.step(),
            Engine::Encoder(e) => e.step(),
        }
    }

    /// Abort one request: drop it from the engine's queues/slots and
    /// release any KV blocks it holds.  Returns whether anything was
    /// dropped.
    fn cancel(&mut self, req_id: u64) -> bool {
        match self {
            Engine::Ar(e) => e.cancel(req_id),
            Engine::Diffusion(e) => e.cancel(req_id),
            Engine::Vocoder(e) => e.cancel(req_id),
            Engine::Encoder(e) => e.cancel(req_id),
        }
    }

    /// Occupancy snapshot for the scheduler's [`crate::scheduler::BatchPolicy`].
    fn view(&self, max_batch: usize) -> EngineView {
        match self {
            Engine::Ar(e) => EngineView {
                running: e.running() + e.queued(),
                max_batch,
                committed_tokens: e.committed_tokens(),
                lane_steps: vec![],
            },
            Engine::Diffusion(e) => EngineView {
                running: e.running() + e.queued(),
                max_batch,
                committed_tokens: 0,
                lane_steps: e.lane_steps(),
            },
            Engine::Vocoder(e) => EngineView {
                running: e.queued(),
                max_batch,
                ..Default::default()
            },
            Engine::Encoder(e) => EngineView {
                running: e.queued(),
                max_batch,
                ..Default::default()
            },
        }
    }
}

pub fn spawn(spec: StageSpec) -> Result<JoinHandle<Result<StageSummary>>> {
    let name = spec.cfg.name.clone();
    let thread_name = if spec.replica == 0 {
        format!("stage-{name}")
    } else {
        format!("stage-{name}-r{}", spec.replica)
    };
    std::thread::Builder::new()
        .name(thread_name)
        .spawn(move || {
            let stage = spec.cfg.name.clone();
            let replica = spec.replica;
            let failed = spec.failed.clone();
            let r = run(spec);
            if let Err(e) = &r {
                eprintln!("stage `{stage}` (replica {replica}) failed: {e:#}");
                failed.store(true, Ordering::SeqCst);
            }
            r
        })
        .map_err(Into::into)
}

fn build_engine(spec: &StageSpec) -> Result<Engine> {
    let c = &spec.cfg;
    Ok(match c.kind {
        StageKind::Ar => {
            let model = spec.artifacts.model(&c.model)?;
            let bytes_per_token = model.cfg_usize("n_layers")?
                * 2
                * model.cfg_usize("n_heads")?
                * model.cfg_usize("d_head")?
                * 4;
            // KV budget: fraction of the stage's device memory, summed
            // over its TP group.
            let kv_bytes = (c.kv_memory_frac
                * c.devices.len() as f64
                * spec.device_bytes as f64) as usize;
            let block_size = 16;
            let kv_blocks = (kv_bytes / bytes_per_token / block_size).max(4);
            let cond_dim = model.cfg_usize("cond_dim").unwrap_or(0);
            let opts = ArEngineOptions {
                max_batch: c.max_batch,
                chunked_prefill: c.chunked_prefill,
                multi_step: c.multi_step,
                stream_chunk: if spec.streaming { c.stream_chunk } else { 0 },
                preprocess: if cond_dim > 0 { Preprocess::UpstreamMean } else { Preprocess::None },
                kv_blocks,
                kv_block_size: block_size,
                lazy_compile: spec.lazy_compile,
                emit_hiddens: true,
                role: c.role,
                prefix_cache: spec.cache.prefix_cache,
                eviction: spec.cache.eviction,
            };
            Engine::Ar(Box::new(ArEngine::new(&spec.artifacts, &c.model, opts)?))
        }
        StageKind::Dit => {
            let opts = DiffusionOptions {
                max_batch: c.max_batch,
                steps: c.diffusion.steps,
                cfg_scale: c.diffusion.cfg_scale,
                stepcache_threshold: c.diffusion.stepcache_threshold,
                lazy_compile: spec.lazy_compile,
            };
            Engine::Diffusion(Box::new(DiffusionEngine::new(&spec.artifacts, &c.model, opts)?))
        }
        StageKind::CnnVocoder => Engine::Vocoder(Box::new(VocoderEngine::new(
            &spec.artifacts,
            &c.model,
            VocoderKind::Cnn,
            c.max_batch,
            spec.lazy_compile,
        )?)),
        StageKind::PatchDecoder => Engine::Vocoder(Box::new(VocoderEngine::new(
            &spec.artifacts,
            &c.model,
            VocoderKind::PatchDecoder,
            c.max_batch,
            spec.lazy_compile,
        )?)),
        StageKind::Encoder => {
            let mut e = EncoderEngine::new(&spec.artifacts, &c.model, c.max_batch)?;
            e.set_cache_capacity(spec.cache.encoder_cache_capacity);
            Engine::Encoder(Box::new(e))
        }
    })
}

/// Removes the replica's time-slice slot when the stage thread exits
/// (any path: drain, retire, failure), so a retired fractional replica
/// stops holding WRR turns on its device.
struct ShareSlotGuard {
    ts: Arc<crate::gpu_share::TimeSlice>,
    id: crate::gpu_share::SlotId,
}

impl Drop for ShareSlotGuard {
    fn drop(&mut self) {
        self.ts.remove_slot(self.id);
    }
}

fn run(mut spec: StageSpec) -> Result<StageSummary> {
    let stage_name: &'static str = Box::leak(spec.cfg.name.clone().into_boxed_str());
    let _share_guard = spec
        .share
        .clone()
        .map(|(ts, id)| ShareSlotGuard { ts, id });
    let engine_result = build_engine(&spec);
    // Rendezvous even on failure so the orchestrator never deadlocks.
    spec.ready.wait();
    let mut engine = engine_result?;

    // Entry AR stages with multimodal inputs own the encoder (paper: the
    // encoder is part of the Thinker stage).
    let mut encoder: Option<StageRuntime> = None;
    if spec.front_rx.is_some() {
        if let Some(enc) = super::encoder_model_for(&spec.cfg.model) {
            if spec.artifacts.models.contains_key(enc) {
                encoder = Some(StageRuntime::new(&spec.artifacts, enc)?);
            }
        }
    }

    // Instantiate incoming transfers with the request table.  The bool
    // tracks edge closure: once an edge reports `TryRecv::Closed` (every
    // producer replica hung up, channels drained) it is never polled
    // again, and when EVERY input has closed the loop drains the engine
    // and exits instead of spinning on dead edges.
    let mut inputs: Vec<(RouterRx, Transfer, bool)> = Vec::new();
    for (rx, tname) in spec.rxs.drain(..) {
        let ctx = TransferCtx {
            reqs: spec.reqs.clone(),
            chunk_frames: spec.downstream_hint.chunk_frames,
            cond_tokens_dim: spec.downstream_hint.cond_tokens_dim,
        };
        let t = spec.registry.instantiate(&tname, ctx)?;
        inputs.push((rx, t, false));
    }

    // The stage's admission queue: inputs land here and the configured
    // batching policy decides what joins the engine at each boundary.
    let mut sched =
        StageScheduler::new(spec.assignment.make_policy(), spec.assignment.queue_depth);
    sched.set_tenant_weights(spec.tenant_weights.clone());

    // Per-request output token counters (for StageDone events).
    let mut tokens_out: HashMap<u64, usize> = HashMap::new();
    let mut first_out: HashMap<u64, bool> = HashMap::new();
    // Requests whose first TOKEN-bearing item this replica has emitted
    // (feeds Event::FirstToken; encoder/vocoder feature items never do).
    let mut first_tok: HashMap<u64, bool> = HashMap::new();
    let mut tick: u64 = 0;
    // Tombstone sweep generation already processed (see the sweep arm).
    let mut cancel_gen: u64 = 0;

    // Event-core wiring: every input edge wakes this worker on pushes and
    // closes.  Items sent before registration are caught by the first
    // body pass below (the loop always ticks once before parking), so no
    // item can be missed in the registration window.
    for (rx, _, _) in &inputs {
        rx.register_wake(spec.wake.clone());
    }
    let wake = spec.wake.clone();
    let mut real = RealDriver::new(spec.clock.clone());

    drive(&mut real, &wake, |_drv| {
        let mut worked = false;
        tick += 1;

        // 1) Frontend requests (entry stage only) — queued, not submitted.
        if let Some(front) = &spec.front_rx {
            while sched.has_room() {
                let Ok(req) = front.try_recv() else { break };
                if spec.cancels.contains(req.id) {
                    // Cancelled between submit and pull: never enters.
                    worked = true;
                    continue;
                }
                let (prio, tenant) = req_sched_keys(&spec.reqs, req.id);
                let cmd = match &mut engine {
                    Engine::Ar(_) => {
                        EngineCmd::SubmitAr(entry_job(&spec, encoder.as_mut(), &req)?)
                    }
                    Engine::Diffusion(e) => {
                        EngineCmd::SubmitDiffusion(diffusion_entry_job(e, &req))
                    }
                    Engine::Vocoder(_) => {
                        EngineCmd::SubmitVocoder(crate::engine::vocoder::VocoderJob {
                            req_id: req.id,
                            chunk_idx: 0,
                            tokens: req.prompt_tokens.clone(),
                            final_chunk: true,
                        })
                    }
                    Engine::Encoder(e) => EngineCmd::SubmitEncode(encode_entry_job(e, &req)),
                };
                for c in sched.enqueue_wfq(cmd, spec.clock.now(), prio, tenant) {
                    apply_cmd(&mut engine, c, stage_name, &spec.recorder, &spec.clock)?;
                }
                worked = true;
            }
        }

        // 2) Upstream items through transfers — submissions queue behind
        // the policy; conditioning rows for in-flight requests pass
        // through.  When the queue-depth cap is hit, items stay in the
        // connector (backpressure on the producer stage).  A `Closed`
        // edge (every producer replica hung up, channels drained) stops
        // being a data source; the loop's stop flag still governs
        // shutdown so in-flight work finishes first.
        for (rx, transfer, closed) in &mut inputs {
            if *closed {
                continue;
            }
            while sched.has_room() {
                let item = match rx.try_recv()? {
                    TryRecv::Item(item) => item,
                    TryRecv::Empty => break,
                    TryRecv::Closed => {
                        *closed = true;
                        break;
                    }
                };
                if spec.cancels.contains(item.req_id) {
                    // Tombstoned mid-flight: the item dies at the edge —
                    // its transfer never runs, so a cancelled request's
                    // KV handoff is never imported and its chunks build
                    // no downstream state.
                    worked = true;
                    continue;
                }
                let (prio, tenant) = req_sched_keys(&spec.reqs, item.req_id);
                for cmd in transfer(&item)? {
                    for c in sched.enqueue_wfq(cmd, spec.clock.now(), prio, tenant) {
                        apply_cmd(&mut engine, c, stage_name, &spec.recorder, &spec.clock)?;
                    }
                }
                worked = true;
            }
        }

        // 2b) Cancellation sweep: when the tombstone generation moved,
        // drop queued submissions from the admission queue and abort
        // in-flight engine work (AR sequences release their KV blocks).
        // One sweep per mark — with no cancellations this is a single
        // atomic load per iteration.
        let g = spec.cancels.generation();
        if g != cancel_gen {
            cancel_gen = g;
            for rid in spec.cancels.snapshot() {
                let dropped = sched.cancel(rid);
                let aborted = engine.cancel(rid);
                if dropped > 0 || aborted {
                    worked = true;
                }
                // Evict per-request state unconditionally: a cancel
                // landing between chunks (nothing queued or in-flight
                // here) would otherwise leak entries forever — the
                // finished item that normally evicts them never arrives
                // for a cancelled request.  Stateful edge transfers
                // (chunk buffers, conditioning accumulators) get a
                // synthetic finished item for the same reason; their
                // resulting commands are DISCARDED, so nothing of the
                // cancelled request enters the engine.
                let tomb = StageItem::new(rid).finished();
                for (_, transfer, closed) in &mut inputs {
                    if !*closed {
                        let _ = transfer(&tomb);
                    }
                }
                tokens_out.remove(&rid);
                first_out.remove(&rid);
                first_tok.remove(&rid);
            }
        }

        // Publish this replica's admission-queue depth so upstream
        // least-depth routers can steer items away from a backed-up
        // replica (scheduler feedback through the router layer), and its
        // load slot so the autoscaler sees queue pressure and idleness.
        {
            let depth = sched.queue_len();
            for (rx, _, _) in &inputs {
                rx.publish_queue_depth(depth);
            }
            if let Some(c) = cache_counters(&engine) {
                spec.slot.publish_cache(&c);
            }
            // Advertise the AR pool's resident prefix hashes so upstream
            // cache-aware routers can steer matching handoffs here.
            // Refreshed at the sampling cadence — coverage is advisory
            // (a stale entry costs one cold first pick, never
            // correctness), so the hot path skips the Vec + lock churn.
            if tick % SAMPLE_EVERY == 0 {
                if let Engine::Ar(e) = &engine {
                    let cover = e.block_manager().resident_hashes();
                    for (rx, _, _) in &inputs {
                        rx.publish_prefix_cover(&cover);
                    }
                }
            }
            spec.slot.publish(depth, !engine.idle());
        }

        // 3) Policy admissions at the token boundary.
        if !sched.is_empty() {
            let view = engine.view(spec.assignment.max_batch);
            let now = spec.clock.now();
            let admissions = sched.ready_with(&view, now, |req, wait_s| {
                spec.recorder.emit(Event::SchedAdmitted {
                    stage: stage_name,
                    replica: spec.replica,
                    req,
                    t: now,
                    wait_s,
                });
            });
            if !admissions.is_empty() {
                worked = true;
                for c in admissions {
                    apply_cmd(&mut engine, c, stage_name, &spec.recorder, &spec.clock)?;
                }
            }
        }

        // Occupancy sample (cheap, periodic).
        if tick % SAMPLE_EVERY == 0 && (!engine.idle() || !sched.is_empty()) {
            let view = engine.view(spec.assignment.max_batch);
            spec.recorder.emit(Event::SchedSample {
                stage: stage_name,
                replica: spec.replica,
                t: spec.clock.now(),
                queued: sched.queue_len(),
                running: view.running,
                committed_tokens: view.committed_tokens,
            });
        }

        // 4) One engine iteration.  On a shared device the step runs
        // under an exclusive time-slice grant: the thread blocks until
        // its slot's turn, and the grant drop charges the held time
        // against the slot's weighted quantum (preemption happens here,
        // at the step boundary — never mid-step).
        if !engine.idle() {
            let items = {
                let _grant = spec.share.as_ref().map(|(ts, id)| ts.acquire(*id));
                engine.step()?
            };
            worked = true;
            for item in items {
                let rid = item.req_id;
                if !first_out.contains_key(&rid) {
                    first_out.insert(rid, true);
                    spec.recorder.emit(Event::StageFirstOutput {
                        req: rid,
                        stage: stage_name,
                        t: spec.clock.now(),
                    });
                }
                if !first_tok.contains_key(&rid)
                    && item.tensor("tokens").map(|t| !t.is_empty()).unwrap_or(false)
                {
                    first_tok.insert(rid, true);
                    spec.recorder.emit(Event::FirstToken { req: rid, t: spec.clock.now() });
                }
                let produced = item
                    .tensor("tokens")
                    .map(|t| t.len())
                    .or_else(|| {
                        item.tensor("n_frames")
                            .and_then(|f| f.as_i32().ok().map(|v| v[0] as usize))
                    })
                    .or_else(|| item.tensor("latent").map(|_| 1))
                    .unwrap_or(0);
                *tokens_out.entry(rid).or_default() += produced;
                if item.finished {
                    let now = spec.clock.now();
                    spec.recorder.emit(Event::StageDone {
                        req: rid,
                        stage: stage_name,
                        t: now,
                        tokens: tokens_out.remove(&rid).unwrap_or(0),
                    });
                    // Streaming API: interior stages mark their finish
                    // on the request's delta stream too.
                    if let Some(hook) = &spec.on_stage_done {
                        hook(rid, stage_name, now);
                    }
                    first_out.remove(&rid);
                    first_tok.remove(&rid);
                }
                // Cache-aware routing hint: an exported KV handoff names
                // its prompt's first full-block chain hash; register it
                // with each outgoing router BEFORE the send so the first
                // pick can prefer a consumer already holding the prefix.
                let sig = item
                    .tensor(crate::kv_transfer::KV_SIG_TENSOR)
                    .and_then(crate::kv_transfer::sig_from_tensor);
                // Forward a copy along every outgoing edge.  A closed
                // connector after shutdown is benign: the run completes
                // when the EXIT stage finishes each request (e.g. the
                // Talker reaches its audio budget before the Thinker
                // drains its last text chunks), so late items are dropped.
                for tx in &mut spec.txs {
                    if let Some(sig) = sig {
                        tx.hint_prompt_signature(item.req_id, sig);
                    }
                    if let Err(e) = tx.send(item.clone()) {
                        if !spec.stop.load(Ordering::SeqCst) {
                            // A downstream edge died mid-run.  Surface a
                            // clean error naming the stranded state (e.g.
                            // a prefill pool whose decode pool is gone
                            // still holds un-exported KV sequences)
                            // instead of hanging on a dead edge.
                            let live = engine.view(spec.assignment.max_batch).running
                                + sched.queue_len();
                            return Err(e.context(format!(
                                "stage `{stage_name}` (replica {}): downstream edge \
                                 closed mid-run with {live} sequence(s) still holding \
                                 KV/stream state",
                                spec.replica
                            )));
                        }
                        // Post-shutdown: the consumer is gone, drop the item.
                    }
                }
                if let Some(sink) = &spec.sink {
                    if sink.send(item).is_ok() {
                        // Unpark the session collector: completed items
                        // are consumed promptly instead of at the next
                        // sweep tick.
                        if let Some(sw) = &spec.sink_wake {
                            sw.wake(WAKE_SINK);
                        }
                    }
                }
            }
        }

        if !worked {
            // Exit on run shutdown, on a per-replica retire signal
            // (elastic scale-down: the control plane has already drained
            // this replica's edges, so an empty engine + queue is final),
            // or once every incoming edge has closed — drain-and-flush:
            // in-flight work finished above, remaining outputs were
            // forwarded, and nothing new can ever arrive, so spinning
            // would hang the stage forever.
            let inputs_closed = spec.front_rx.is_none()
                && !inputs.is_empty()
                && inputs.iter().all(|(_, _, closed)| *closed);
            if should_exit(
                spec.stop.load(Ordering::SeqCst),
                spec.retire.load(Ordering::SeqCst),
                inputs_closed,
                engine.idle(),
                sched.is_empty(),
            ) {
                return Ok(Tick::Exit);
            }
            // Nothing to do: park until an edge push/close, frontend
            // submission, cancel tombstone, or control command wakes
            // us (the real driver's backstop bounds the sleep).
            return Ok(Tick::Idle(None));
        }
        Ok(Tick::Progress)
    })?;
    // Final load publication: a retired/stopped replica holds no work.
    spec.slot.publish(0, false);

    // Final cache snapshot: one absolute-counter event per replica (the
    // recorder keeps the latest, so this IS the run's total) plus the
    // live slot for post-run `stats` reads.
    let cache = cache_counters(&engine);
    if let Some(c) = cache {
        spec.slot.publish_cache(&c);
        spec.recorder.emit(Event::CacheStats {
            stage: stage_name,
            replica: spec.replica,
            t: spec.clock.now(),
            counters: c,
        });
    }

    let mut summary = StageSummary {
        name: spec.cfg.name.clone(),
        replica: spec.replica,
        cache,
        ..Default::default()
    };
    match engine {
        Engine::Ar(e) => summary.ar = Some(e.stats.clone()),
        Engine::Diffusion(e) => summary.diffusion = Some(e.stats.clone()),
        Engine::Vocoder(e) => summary.vocoder = Some(e.stats.clone()),
        Engine::Encoder(_) => {}
    }
    summary.sched = Some(sched.stats.clone());
    summary.bytes_sent = spec.txs.iter().map(|t| t.bytes_sent()).sum();
    let wc = spec.wake.counters();
    summary.wakeups = wc.wakeups;
    summary.spurious_wakeups = wc.spurious_wakeups;
    summary.idle_ms = wc.idle_ns as f64 / 1e6;
    Ok(summary)
}

/// Current cross-request cache counters of the engine kinds that cache
/// (`None` for diffusion/vocoder engines, which hold no cache).
fn cache_counters(engine: &Engine) -> Option<crate::metrics::CacheCounters> {
    match engine {
        Engine::Ar(e) => {
            let m = e.block_manager();
            Some(crate::metrics::CacheCounters {
                prefix_hits: m.prefix_hits,
                prefix_misses: m.prefix_misses,
                evictions: m.evictions,
                ..Default::default()
            })
        }
        Engine::Encoder(e) => Some(crate::metrics::CacheCounters {
            encoder_hits: e.stats.cache_hits,
            encoder_misses: e.stats.cache_misses,
            ..Default::default()
        }),
        _ => None,
    }
}

/// When the stage loop may stop serving (pure; see the loop's exit arm).
/// `inputs_closed` alone is enough once the engine has drained: every
/// producer replica of every incoming edge hung up, so no item can ever
/// arrive again — without this arm a stage whose upstream died would spin
/// on dead edges forever instead of exiting cleanly.
fn should_exit(
    stop: bool,
    retire: bool,
    inputs_closed: bool,
    engine_idle: bool,
    queue_empty: bool,
) -> bool {
    (stop || retire || inputs_closed) && engine_idle && queue_empty
}

/// Resolve a request's admission priority and WFQ tenant id from the
/// shared metadata table (unknown requests — e.g. engine-level tests —
/// rank normal under the anonymous tenant).
fn req_sched_keys(reqs: &ReqTable, req_id: u64) -> (u8, u32) {
    reqs.lock()
        .unwrap()
        .get(&req_id)
        .map(|m| (m.priority, m.tenant))
        .unwrap_or((crate::scheduler::PRIORITY_NORMAL, 0))
}

fn apply_cmd(
    engine: &mut Engine,
    cmd: EngineCmd,
    stage_name: &'static str,
    recorder: &Recorder,
    clock: &RunClock,
) -> Result<()> {
    match (engine, cmd) {
        (Engine::Ar(e), EngineCmd::SubmitAr(job)) => {
            recorder.emit(Event::StageAdmit { req: job.req_id, stage: stage_name, t: clock.now() });
            e.submit(job);
        }
        (Engine::Ar(e), EngineCmd::Upstream { req_id, rows, dim, complete }) => {
            e.push_upstream(req_id, &rows, dim.max(1), complete);
        }
        (Engine::Ar(e), EngineCmd::SubmitKv(h)) => {
            recorder.emit(Event::StageAdmit { req: h.req_id, stage: stage_name, t: clock.now() });
            e.submit_handoff(*h)?;
        }
        (Engine::Diffusion(e), EngineCmd::SubmitDiffusion(job)) => {
            if job.chunk_idx == 0 {
                recorder.emit(Event::StageAdmit {
                    req: job.req_id,
                    stage: stage_name,
                    t: clock.now(),
                });
            }
            e.submit(job);
        }
        (Engine::Vocoder(e), EngineCmd::SubmitVocoder(job)) => {
            if job.chunk_idx == 0 {
                recorder.emit(Event::StageAdmit {
                    req: job.req_id,
                    stage: stage_name,
                    t: clock.now(),
                });
            }
            e.submit(job);
        }
        (Engine::Encoder(e), EngineCmd::SubmitEncode(job)) => {
            recorder.emit(Event::StageAdmit { req: job.req_id, stage: stage_name, t: clock.now() });
            e.submit(job);
        }
        (_, cmd) => bail!("stage `{stage_name}`: engine cannot handle {cmd:?}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::should_exit;

    #[test]
    fn closed_inputs_drain_then_exit() {
        // A stage whose every incoming edge closed exits once drained...
        assert!(should_exit(false, false, true, true, true));
        // ...but never while the engine or the admission queue still hold
        // work (drain-and-flush: in-flight sequences finish first).
        assert!(!should_exit(false, false, true, false, true));
        assert!(!should_exit(false, false, true, true, false));
        // Live inputs and no stop/retire: keep serving.
        assert!(!should_exit(false, false, false, true, true));
        // Stop/retire still exit exactly as before.
        assert!(should_exit(true, false, false, true, true));
        assert!(should_exit(false, true, false, true, true));
        assert!(!should_exit(true, false, false, false, true));
    }
}

/// Entry job for a standalone encoder stage (EPD disaggregation):
/// synthesize the request's multimodal features exactly as the fused
/// Thinker-side encoder path does, so EPD and fused modes agree.
fn encode_entry_job(eng: &EncoderEngine, req: &Request) -> EncodeJob {
    let frames = req.mm_frames.min(eng.t_max());
    let fd = eng.feat_dim();
    let mut prng = Prng::new(req.seed ^ 0x33C0DE);
    let mut feats = vec![0f32; frames * fd];
    for x in feats.iter_mut() {
        *x = prng.normal() as f32 * 0.5;
    }
    EncodeJob { req_id: req.id, feats, frames }
}

/// Entry job for a standalone DiT stage (Fig. 8 single-model pipelines):
/// the text/image conditioning encoder is not part of these pipelines, so
/// conditioning features are synthesized deterministically from the
/// prompt tokens (and mm seed for image-conditioned tasks).
fn diffusion_entry_job(
    eng: &crate::engine::diffusion::DiffusionEngine,
    req: &Request,
) -> crate::engine::diffusion::DiffusionJob {
    let cd = eng.cond_dim();
    let mut cond = vec![0f32; cd];
    for (i, &t) in req.prompt_tokens.iter().enumerate() {
        for (j, c) in cond.iter_mut().enumerate() {
            *c += ((t as f32) * 0.013 + (i as f32) * 0.61 + (j as f32) * 0.29).sin();
        }
    }
    let norm = (req.prompt_tokens.len().max(1)) as f32;
    cond.iter_mut().for_each(|c| *c /= norm);
    // Image-conditioned tasks (I2I / I2V) mix in reference-image features.
    if req.mm_frames > 0 {
        let mut prng = Prng::new(req.seed ^ 0x1A6E);
        for c in cond.iter_mut() {
            *c += prng.normal() as f32 * 0.2;
        }
    }
    crate::engine::diffusion::DiffusionJob {
        req_id: req.id,
        chunk_idx: 0,
        cond,
        cond_tokens: vec![],
        seed: req.seed,
        steps: req.diffusion_steps,
        final_chunk: true,
    }
}

/// Build the entry-stage job for a frontend request: text tokens plus,
/// for multimodal requests, encoder embeddings (the Thinker-side
/// `mm_encode` preprocess from the paper's Fig. 4).
fn entry_job(spec: &StageSpec, encoder: Option<&mut StageRuntime>, req: &Request) -> Result<ArJob> {
    let mut prompt: Vec<PromptItem> =
        req.prompt_tokens.iter().map(|&t| PromptItem::Token(t)).collect();
    let mut mm_embeds: Vec<f32> = vec![];
    let mut emb_dim = 0usize;

    if req.mm_frames > 0 {
        let Some(enc) = encoder else {
            // Stages without a dedicated encoder (e.g. BAGEL's
            // understanding expert, whose ViT is folded into the stage)
            // consume synthetic reference-image embeddings directly.
            let model = spec.artifacts.model(&spec.cfg.model)?;
            let d = model.cfg_usize("d_model")?;
            let mut prng = Prng::new(req.seed ^ 0x77E1);
            emb_dim = d;
            mm_embeds.extend((0..req.mm_frames * d).map(|_| prng.normal() as f32 * 0.1));
            prompt.extend((0..req.mm_frames).map(PromptItem::Embed));
            return Ok(ArJob {
                req_id: req.id,
                prompt,
                mm_embeds,
                emb_dim,
                sampling: SamplingParams {
                    max_new_tokens: req.max_text_tokens.max(1),
                    temperature: 0.0,
                    top_k: 0,
                    ignore_eos: req.ignore_eos,
                    seed: req.seed,
                },
            });
        };
        let spec_m = enc.model().clone();
        let t_max = spec_m.cfg_usize("t_max")?;
        let feat_dim = spec_m.cfg_usize("feat_dim")?;
        let d_out = spec_m.cfg_usize("d_out")?;
        let frames = req.mm_frames.min(t_max);
        // Deterministic synthetic features standing in for audio/image/
        // video frontends (DESIGN.md §7).
        let mut prng = Prng::new(req.seed ^ 0x33C0DE);
        let mut feats = vec![0f32; t_max * feat_dim];
        for x in feats.iter_mut().take(frames * feat_dim) {
            *x = prng.normal() as f32 * 0.5;
        }
        let mut mask = vec![0f32; t_max];
        for m in mask.iter_mut().take(frames) {
            *m = 1.0;
        }
        let entry = spec_m.bucket_entry("encode", 1, "")?;
        let outs = enc.run(
            &entry,
            &[
                HostTensor::f32(vec![1, t_max, feat_dim], feats),
                HostTensor::f32(vec![1, t_max], mask),
            ],
        )?;
        let embeds = outs[0].as_f32()?;
        emb_dim = d_out;
        mm_embeds.extend_from_slice(&embeds[..frames * d_out]);
        let base = prompt.len();
        let _ = base;
        let start = mm_embeds.len() / d_out - frames;
        prompt.extend((start..start + frames).map(PromptItem::Embed));
    }

    Ok(ArJob {
        req_id: req.id,
        prompt,
        mm_embeds,
        emb_dim,
        sampling: SamplingParams {
            max_new_tokens: req.max_text_tokens.max(1),
            temperature: 0.0,
            top_k: 0,
            ignore_eos: req.ignore_eos,
            seed: req.seed,
        },
    })
}
