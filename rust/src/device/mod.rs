//! Simulated accelerator pool (paper §4.1 testbed: 2 devices x 80 GB).
//!
//! Compute executes on the CPU PJRT client; this module models the
//! *resource-allocation* half of the paper's contribution: per-stage
//! device placement, memory budgets, and tensor-parallel degree.  Configs
//! that over-subscribe a device are rejected at pipeline-build time, the
//! same admission role the real system's allocator plays.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

/// Monotonic reservation ids (process-wide): each successful `reserve`
/// gets one, so release can be idempotent across `Reservation` clones
/// and the pool can audit what is still outstanding.
static NEXT_RES_ID: AtomicU64 = AtomicU64::new(1);

/// Scaled testbed: the paper uses 2 x 80 GB; our models are ~1000x
/// smaller, so the default pool is 2 x 80 MB to keep admission pressure
/// realistic (a mis-placed pipeline actually fails).
pub const DEFAULT_DEVICE_BYTES: usize = 80 * 1024 * 1024;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DeviceId(pub usize);

#[derive(Debug)]
struct Device {
    total: usize,
    used: usize,
}

/// A pool of simulated accelerators with memory accounting.
#[derive(Debug)]
pub struct DevicePool {
    devices: Mutex<Vec<Device>>,
    /// Live (not yet released) reservations by id — the release-once
    /// gate and the leak audit ([`DevicePool::outstanding`]).
    live: Mutex<HashMap<u64, (usize, String)>>,
}

/// A successful reservation; freeing is explicit (engines hold these for
/// their lifetime).  Releasing is idempotent per reservation *id*, so
/// releasing both a clone and its original subtracts exactly once.
#[derive(Debug, Clone)]
pub struct Reservation {
    pub device: DeviceId,
    pub bytes: usize,
    pub label: String,
    id: u64,
}

impl DevicePool {
    pub fn new(n_devices: usize, bytes_per_device: usize) -> Self {
        let devices = (0..n_devices).map(|_| Device { total: bytes_per_device, used: 0 }).collect();
        Self { devices: Mutex::new(devices), live: Mutex::new(HashMap::new()) }
    }

    /// The paper's testbed: two 80 GB accelerators (scaled).
    pub fn testbed() -> Self {
        Self::new(2, DEFAULT_DEVICE_BYTES)
    }

    pub fn n_devices(&self) -> usize {
        self.devices.lock().unwrap().len()
    }

    /// Reserve `bytes` on `device`, failing if the budget is exceeded.
    pub fn reserve(&self, device: DeviceId, bytes: usize, label: &str) -> Result<Reservation> {
        let mut devs = self.devices.lock().unwrap();
        let d = devs
            .get_mut(device.0)
            .ok_or_else(|| anyhow!("no such device {}", device.0))?;
        if d.used + bytes > d.total {
            bail!(
                "device {} over budget: {} used + {} requested ({label}) > {} total",
                device.0,
                d.used,
                bytes,
                d.total
            );
        }
        d.used += bytes;
        drop(devs);
        let id = NEXT_RES_ID.fetch_add(1, Ordering::Relaxed);
        self.live.lock().unwrap().insert(id, (bytes, label.to_string()));
        Ok(Reservation { device, bytes, label: label.to_string(), id })
    }

    /// Reserve a tensor-parallel allocation: `bytes` split evenly across
    /// `devices` (the paper's "Thinker TP across both accelerators").
    pub fn reserve_tp(&self, devices: &[DeviceId], bytes: usize, label: &str) -> Result<Vec<Reservation>> {
        if devices.is_empty() {
            bail!("tensor-parallel group is empty ({label})");
        }
        let shard = bytes.div_ceil(devices.len());
        let mut done = Vec::with_capacity(devices.len());
        for (i, &d) in devices.iter().enumerate() {
            match self.reserve(d, shard, &format!("{label}.tp{i}")) {
                Ok(r) => done.push(r),
                Err(e) => {
                    for r in done {
                        self.release(&r);
                    }
                    return Err(e);
                }
            }
        }
        Ok(done)
    }

    pub fn release(&self, r: &Reservation) {
        // Release-once gate: a reservation already released (possibly
        // through a clone — the autoscaler hands clones around) must not
        // subtract again.
        if self.live.lock().unwrap().remove(&r.id).is_none() {
            return;
        }
        let mut devs = self.devices.lock().unwrap();
        if let Some(d) = devs.get_mut(r.device.0) {
            d.used = d.used.saturating_sub(r.bytes);
        }
    }

    /// Bytes reserved on `device`; 0 for an unknown device id, mirroring
    /// `release()`'s tolerance instead of panicking on a bad index.
    pub fn used(&self, device: DeviceId) -> usize {
        self.devices.lock().unwrap().get(device.0).map(|d| d.used).unwrap_or(0)
    }

    /// Bytes still unreserved on `device`; 0 for an unknown device id.
    pub fn free(&self, device: DeviceId) -> usize {
        self.devices.lock().unwrap().get(device.0).map(|d| d.total - d.used).unwrap_or(0)
    }

    /// Leak audit: every reservation handed out and not yet released, as
    /// `(label, bytes)`.  Tests wrap teardown with an emptiness assert so
    /// a replica path that forgets `release()` fails an invariant instead
    /// of silently shrinking the pool.
    pub fn outstanding(&self) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> =
            self.live.lock().unwrap().values().map(|(b, l)| (l.clone(), *b)).collect();
        v.sort();
        v
    }
}

/// RAII debug guard over a [`Reservation`]: releases on drop unless
/// explicitly kept with [`ScopedReservation::into_inner`].  Paths that
/// reserve-then-maybe-fail (allocator packing, autoscaler scale-up) hold
/// their reservations through this so every early return frees memory.
pub struct ScopedReservation<'a> {
    pool: &'a DevicePool,
    res: Option<Reservation>,
}

impl<'a> ScopedReservation<'a> {
    pub fn new(pool: &'a DevicePool, res: Reservation) -> Self {
        Self { pool, res: Some(res) }
    }

    pub fn get(&self) -> &Reservation {
        self.res.as_ref().expect("reservation held until drop")
    }

    /// Keep the reservation past the guard's scope (ownership transfer to
    /// a long-lived holder, e.g. a spawned replica).
    pub fn into_inner(mut self) -> Reservation {
        self.res.take().expect("reservation held until drop")
    }
}

impl Drop for ScopedReservation<'_> {
    fn drop(&mut self) {
        if let Some(r) = self.res.take() {
            self.pool.release(&r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;

    #[test]
    fn reserve_and_release() {
        let p = DevicePool::new(2, 1000);
        let r = p.reserve(DeviceId(0), 600, "w").unwrap();
        assert_eq!(p.used(DeviceId(0)), 600);
        assert!(p.reserve(DeviceId(0), 600, "x").is_err());
        p.release(&r);
        assert_eq!(p.used(DeviceId(0)), 0);
        assert!(p.reserve(DeviceId(0), 600, "x").is_ok());
    }

    #[test]
    fn tp_split_is_even_and_atomic() {
        let p = DevicePool::new(2, 1000);
        let rs = p.reserve_tp(&[DeviceId(0), DeviceId(1)], 1000, "thinker").unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(p.used(DeviceId(0)), 500);
        assert_eq!(p.used(DeviceId(1)), 500);
        // Over-subscription on ANY member must roll back the whole group.
        let _fill = p.reserve(DeviceId(1), 400, "talker").unwrap();
        let err = p.reserve_tp(&[DeviceId(0), DeviceId(1)], 400, "big");
        assert!(err.is_err());
        assert_eq!(p.used(DeviceId(0)), 500, "rollback failed");
    }

    #[test]
    fn invalid_device_rejected() {
        let p = DevicePool::new(1, 10);
        assert!(p.reserve(DeviceId(3), 1, "x").is_err());
    }

    #[test]
    fn out_of_range_queries_return_zero() {
        // Regression: `used`/`free` indexed the device vec unchecked and
        // panicked on an out-of-range id; they now answer 0, mirroring
        // release()'s tolerance.
        let p = DevicePool::new(2, 1000);
        assert_eq!(p.used(DeviceId(7)), 0);
        assert_eq!(p.free(DeviceId(7)), 0);
        let _r = p.reserve(DeviceId(0), 100, "w").unwrap();
        assert_eq!(p.used(DeviceId(usize::MAX)), 0);
        assert_eq!(p.free(DeviceId(usize::MAX)), 0);
    }

    #[test]
    fn release_is_idempotent_across_clones() {
        let p = DevicePool::new(1, 1000);
        let r = p.reserve(DeviceId(0), 400, "w").unwrap();
        let c = r.clone();
        p.release(&c);
        assert_eq!(p.used(DeviceId(0)), 0);
        // Second release through the original must not underflow or
        // double-subtract against later reservations.
        p.release(&r);
        let _again = p.reserve(DeviceId(0), 1000, "x").unwrap();
        assert_eq!(p.used(DeviceId(0)), 1000);
    }

    #[test]
    fn outstanding_audit_catches_leaks() {
        let p = DevicePool::new(2, 1000);
        let a = p.reserve(DeviceId(0), 100, "thinker").unwrap();
        let _leaked = p.reserve(DeviceId(1), 200, "vocoder").unwrap();
        p.release(&a);
        // The forgotten reservation surfaces by label in the audit.
        assert_eq!(p.outstanding(), vec![("vocoder".to_string(), 200)]);
    }

    #[test]
    fn scoped_reservation_releases_on_drop() {
        let p = DevicePool::new(1, 1000);
        {
            let g = ScopedReservation::new(&p, p.reserve(DeviceId(0), 300, "w").unwrap());
            assert_eq!(g.get().bytes, 300);
            assert_eq!(p.used(DeviceId(0)), 300);
        }
        assert_eq!(p.used(DeviceId(0)), 0);
        assert!(p.outstanding().is_empty());
        // into_inner transfers ownership: nothing released at drop.
        let kept = {
            let g = ScopedReservation::new(&p, p.reserve(DeviceId(0), 300, "w").unwrap());
            g.into_inner()
        };
        assert_eq!(p.used(DeviceId(0)), 300);
        p.release(&kept);
        assert!(p.outstanding().is_empty());
    }

    #[test]
    fn prop_reserve_tp_failure_restores_exact_usage() {
        // Satellite: a mid-group reservation failure must leave every
        // device's `used` bytes exactly as before the call.
        quick("reserve_tp_rollback", |rng| {
            let n = rng.range(2, 5);
            let total = rng.range(200, 5_000);
            let p = DevicePool::new(n, total);
            // Random pre-existing load.
            let mut held: Vec<Reservation> = vec![];
            for d in 0..n {
                if rng.bool(0.7) {
                    let b = rng.range(1, total);
                    if let Ok(r) = p.reserve(DeviceId(d), b, "pre") {
                        held.push(r);
                    }
                }
            }
            let before: Vec<usize> = (0..n).map(|d| p.used(DeviceId(d))).collect();
            let group: Vec<DeviceId> = (0..n).map(DeviceId).collect();
            let bytes = rng.range(1, total * n);
            match p.reserve_tp(&group, bytes, "tp") {
                Ok(rs) => {
                    for r in &rs {
                        p.release(r);
                    }
                    let after: Vec<usize> = (0..n).map(|d| p.used(DeviceId(d))).collect();
                    assert_eq!(before, after, "release after success must restore usage");
                }
                Err(_) => {
                    let after: Vec<usize> = (0..n).map(|d| p.used(DeviceId(d))).collect();
                    assert_eq!(before, after, "failed reserve_tp must roll back exactly");
                }
            }
            for r in &held {
                p.release(r);
            }
            assert!(p.outstanding().is_empty(), "leak audit after full release");
        });
    }

    #[test]
    fn prop_accounting_never_exceeds_total() {
        quick("device_accounting", |rng| {
            let total = rng.range(100, 10_000);
            let p = DevicePool::new(2, total);
            let mut held: Vec<Reservation> = vec![];
            for _ in 0..rng.range(1, 40) {
                if rng.bool(0.6) || held.is_empty() {
                    let d = DeviceId(rng.range(0, 1));
                    let b = rng.range(1, total / 2);
                    if let Ok(r) = p.reserve(d, b, "t") {
                        held.push(r);
                    }
                } else {
                    let i = rng.range(0, held.len() - 1);
                    let r = held.swap_remove(i);
                    p.release(&r);
                }
                for d in 0..2 {
                    assert!(p.used(DeviceId(d)) <= total);
                }
            }
        });
    }
}
