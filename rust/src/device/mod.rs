//! Simulated accelerator pool (paper §4.1 testbed: 2 devices x 80 GB).
//!
//! Compute executes on the CPU PJRT client; this module models the
//! *resource-allocation* half of the paper's contribution: per-stage
//! device placement, memory budgets, and tensor-parallel degree.  Configs
//! that over-subscribe a device are rejected at pipeline-build time, the
//! same admission role the real system's allocator plays.

use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

/// Scaled testbed: the paper uses 2 x 80 GB; our models are ~1000x
/// smaller, so the default pool is 2 x 80 MB to keep admission pressure
/// realistic (a mis-placed pipeline actually fails).
pub const DEFAULT_DEVICE_BYTES: usize = 80 * 1024 * 1024;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DeviceId(pub usize);

#[derive(Debug)]
struct Device {
    total: usize,
    used: usize,
}

/// A pool of simulated accelerators with memory accounting.
#[derive(Debug)]
pub struct DevicePool {
    devices: Mutex<Vec<Device>>,
}

/// A successful reservation; freeing is explicit (engines hold these for
/// their lifetime).
#[derive(Debug, Clone)]
pub struct Reservation {
    pub device: DeviceId,
    pub bytes: usize,
    pub label: String,
}

impl DevicePool {
    pub fn new(n_devices: usize, bytes_per_device: usize) -> Self {
        let devices = (0..n_devices).map(|_| Device { total: bytes_per_device, used: 0 }).collect();
        Self { devices: Mutex::new(devices) }
    }

    /// The paper's testbed: two 80 GB accelerators (scaled).
    pub fn testbed() -> Self {
        Self::new(2, DEFAULT_DEVICE_BYTES)
    }

    pub fn n_devices(&self) -> usize {
        self.devices.lock().unwrap().len()
    }

    /// Reserve `bytes` on `device`, failing if the budget is exceeded.
    pub fn reserve(&self, device: DeviceId, bytes: usize, label: &str) -> Result<Reservation> {
        let mut devs = self.devices.lock().unwrap();
        let d = devs
            .get_mut(device.0)
            .ok_or_else(|| anyhow!("no such device {}", device.0))?;
        if d.used + bytes > d.total {
            bail!(
                "device {} over budget: {} used + {} requested ({label}) > {} total",
                device.0,
                d.used,
                bytes,
                d.total
            );
        }
        d.used += bytes;
        Ok(Reservation { device, bytes, label: label.to_string() })
    }

    /// Reserve a tensor-parallel allocation: `bytes` split evenly across
    /// `devices` (the paper's "Thinker TP across both accelerators").
    pub fn reserve_tp(&self, devices: &[DeviceId], bytes: usize, label: &str) -> Result<Vec<Reservation>> {
        if devices.is_empty() {
            bail!("tensor-parallel group is empty ({label})");
        }
        let shard = bytes.div_ceil(devices.len());
        let mut done = Vec::with_capacity(devices.len());
        for (i, &d) in devices.iter().enumerate() {
            match self.reserve(d, shard, &format!("{label}.tp{i}")) {
                Ok(r) => done.push(r),
                Err(e) => {
                    for r in done {
                        self.release(&r);
                    }
                    return Err(e);
                }
            }
        }
        Ok(done)
    }

    pub fn release(&self, r: &Reservation) {
        let mut devs = self.devices.lock().unwrap();
        if let Some(d) = devs.get_mut(r.device.0) {
            d.used = d.used.saturating_sub(r.bytes);
        }
    }

    pub fn used(&self, device: DeviceId) -> usize {
        self.devices.lock().unwrap()[device.0].used
    }

    pub fn free(&self, device: DeviceId) -> usize {
        let devs = self.devices.lock().unwrap();
        devs[device.0].total - devs[device.0].used
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;

    #[test]
    fn reserve_and_release() {
        let p = DevicePool::new(2, 1000);
        let r = p.reserve(DeviceId(0), 600, "w").unwrap();
        assert_eq!(p.used(DeviceId(0)), 600);
        assert!(p.reserve(DeviceId(0), 600, "x").is_err());
        p.release(&r);
        assert_eq!(p.used(DeviceId(0)), 0);
        assert!(p.reserve(DeviceId(0), 600, "x").is_ok());
    }

    #[test]
    fn tp_split_is_even_and_atomic() {
        let p = DevicePool::new(2, 1000);
        let rs = p.reserve_tp(&[DeviceId(0), DeviceId(1)], 1000, "thinker").unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(p.used(DeviceId(0)), 500);
        assert_eq!(p.used(DeviceId(1)), 500);
        // Over-subscription on ANY member must roll back the whole group.
        let _fill = p.reserve(DeviceId(1), 400, "talker").unwrap();
        let err = p.reserve_tp(&[DeviceId(0), DeviceId(1)], 400, "big");
        assert!(err.is_err());
        assert_eq!(p.used(DeviceId(0)), 500, "rollback failed");
    }

    #[test]
    fn invalid_device_rejected() {
        let p = DevicePool::new(1, 10);
        assert!(p.reserve(DeviceId(3), 1, "x").is_err());
    }

    #[test]
    fn prop_accounting_never_exceeds_total() {
        quick("device_accounting", |rng| {
            let total = rng.range(100, 10_000);
            let p = DevicePool::new(2, total);
            let mut held: Vec<Reservation> = vec![];
            for _ in 0..rng.range(1, 40) {
                if rng.bool(0.6) || held.is_empty() {
                    let d = DeviceId(rng.range(0, 1));
                    let b = rng.range(1, total / 2);
                    if let Ok(r) = p.reserve(d, b, "t") {
                        held.push(r);
                    }
                } else {
                    let i = rng.range(0, held.len() - 1);
                    let r = held.swap_remove(i);
                    p.release(&r);
                }
                for d in 0..2 {
                    assert!(p.used(DeviceId(d)) <= total);
                }
            }
        });
    }
}
