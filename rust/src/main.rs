//! omni-serve launcher: `serve`, `run`, `bench`, `replay`, `graph`, `baseline`.

use std::sync::Arc;

use anyhow::{bail, Context, Result};
use omni_serve::cli::Args;
use omni_serve::config::{loader, presets};
use omni_serve::orchestrator::{Orchestrator, RunOptions};
use omni_serve::runtime::Artifacts;
use omni_serve::stage_graph::transfers::Registry;
use omni_serve::trace::datasets;
use omni_serve::util::fmt;

const USAGE: &str = "\
omni-serve — fully disaggregated serving for any-to-any multimodal models

USAGE:
  omni-serve serve --pipeline <name> [--addr 127.0.0.1:8090] [--port 8090]
                   [--autoscale] [--gpu-budget N] [--config file.json]
                   [--admission] [--slack X] [--shed-horizon S] [--retry-after S]
                   (--admission turns SLO-aware overload control on: requests
                    whose deadline is unmeetable get a structured rejection at
                    submit time, and queued work is shed earliest-deadline-first
                    when the backlog projects past the horizon)
                   [--no-prefix-cache] [--eviction lru|hit_aware] [--encoder-cache N]
                   (the global prefix cache and the encoder-output cache are ON
                    by default; these knobs disable or retune them — the `stats`
                    op reports hit rates live)
  omni-serve run   --pipeline <name> --dataset <librispeech|food101|ucf101|seedtts|vbench|bursty|prefill-heavy|shared-prefix|branching>
                   [--n 8] [--rate 0] [--seed 1] [--no-streaming] [--baseline]
                   [--no-prefix-cache] [--eviction lru|hit_aware] [--encoder-cache N]
                   [--deadline S]   (cancel each request end-to-end S seconds
                                     after submission; the summary reports
                                     cancelled counts + freed KV)
  omni-serve bench [--trace bursty|bursty-mixed|librispeech|seedtts|prefill-heavy|overload-storm|shared-prefix|cross-node|fractional]
                   [--n 48] [--budget 4] [--seeds 32] [--event-core]
                   [--replay-record] [--replay-path file.evl]
                   (artifact-free: autoscaled vs static replica splits on the AR-stage
                    model; `prefill-heavy` runs the P/D-disaggregation comparison —
                    fused vs split prefill/decode pools — and exits non-zero unless
                    the split wins; `overload-storm` runs admission+shedding vs
                    FIFO-with-deadlines at 2x/3x/5x offered load and exits non-zero
                    unless admission wins on goodput for every seed; `shared-prefix`
                    runs the prefix-cache comparison — cached vs cold on the
                    shared-prefix trace — and exits non-zero unless cached wins
                    both TTFT and JCT for every seed; `cross-node` runs the
                    cluster-placement comparison — transfer-aware vs round-robin
                    replica→node assignment at equal hardware — and exits non-zero
                    unless transfer-aware wins mean JCT for every seed;
                    `fractional` runs the fractional-GPU comparison — encoder +
                    vocoder carved onto one shared device buying a third DiT
                    replica vs whole-device packing on the branching fan-out
                    trace — and exits non-zero unless the packed-fractional
                    layout wins mean JCT for every seed; `bursty-mixed
                    --event-core` runs the event-driven-core comparison —
                    parked-worker wakeups vs bounded-backoff polling on the
                    FCFS lane executor — and exits non-zero unless the event
                    core wins mean JCT and p95 queue-wait for every seed —
                    all six are CI smoke gates; `bursty-mixed --replay-record`
                    captures one seeded run as an OEVL event log that
                    `omni-serve replay` re-drives bit-for-bit)
  omni-serve replay <log.evl>
                   (re-drive a recorded OEVL event log deterministically and
                    print the canonical replay report line; a log that carries
                    execution events must regenerate them bit-for-bit or this
                    command exits non-zero — record one with `bench --trace
                    bursty-mixed --replay-record` or a serving session's
                    `runtime.replay_record` config block)
  omni-serve agent --node-id <id> --listen <host:port> [--gpus 2] [--device-bytes N]
                   [--heartbeat 0.25] [--read-timeout 5.0]
                   (multi-node mode: host this machine's share of a pipeline —
                    bind, print the bound address, register with the controller
                    that connects, host assigned stage replicas, heartbeat,
                    drain on request; see docs/architecture.md §13)
  omni-serve graph [--pipeline <name>] [--list]
  omni-serve help

Pipelines: qwen2.5-omni, qwen3-omni, qwen3-omni-rep2, qwen3-omni-epd,
           qwen3-omni-branching, bagel-t2i, bagel-i2i, mimo-audio,
           mimo-audio-compiled, qwen-image, qwen-image-edit, wan22-t2v, wan22-i2v
";

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn pipeline_from(args: &Args) -> Result<omni_serve::config::PipelineConfig> {
    if let Some(path) = args.flag("config") {
        return loader::from_file(std::path::Path::new(path));
    }
    let name = args.flag("pipeline").unwrap_or("qwen3-omni");
    presets::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown pipeline `{name}` (see `omni-serve help`)"))
}

/// Cache overrides from the CLI (`--no-prefix-cache`, `--eviction`,
/// `--encoder-cache`): `None` when no knob is present, so the pipeline's
/// own `cache` block (or the built-in default: everything on) applies.
fn cache_from(
    args: &Args,
    base: Option<&omni_serve::config::CacheConfig>,
) -> Result<Option<omni_serve::config::CacheConfig>> {
    let knobs = args.flag_bool("no-prefix-cache")
        || args.flag("eviction").is_some()
        || args.flag("encoder-cache").is_some();
    if !knobs {
        return Ok(None);
    }
    let mut c = base.cloned().unwrap_or_default();
    c.prefix_cache = !args.flag_bool("no-prefix-cache");
    if let Some(name) = args.flag("eviction") {
        c.eviction = omni_serve::kv_cache::EvictionPolicy::from_name(name)?;
    }
    c.encoder_cache_capacity = args.flag_usize("encoder-cache", c.encoder_cache_capacity)?;
    Ok(Some(c))
}

fn real_main() -> Result<()> {
    let args = Args::from_env()?;
    match args.command.as_str() {
        "serve" => {
            let config = pipeline_from(&args)?;
            let artifacts = Arc::new(Artifacts::load(&Artifacts::default_dir())?);
            // `--port` overrides the port of `--addr` (default host kept).
            let addr = args.flag("addr").unwrap_or("127.0.0.1:8090").to_string();
            let addr = match args.flag("port") {
                Some(p) => {
                    let host = addr.rsplit_once(':').map(|(h, _)| h).unwrap_or("127.0.0.1");
                    format!("{host}:{p}")
                }
                None => addr,
            };
            // `--autoscale` turns the elastic control plane on (defaults
            // from the config's `autoscaler` block or AutoscalerConfig);
            // `--gpu-budget` caps total device slots across all replicas.
            let autoscaler = if args.flag_bool("autoscale") || args.flag("gpu-budget").is_some() {
                let mut a = config.autoscaler.clone().unwrap_or_default();
                if args.flag("gpu-budget").is_some() {
                    a.gpu_budget = args.flag_usize("gpu-budget", a.gpu_budget)?;
                }
                Some(a)
            } else {
                None
            };
            // `--admission` (or any of its knobs) turns SLO-aware
            // overload control on, defaulting from the config's
            // `admission` block; knob flags override individually.
            let knobs =
                ["slack", "shed-horizon", "retry-after"].iter().any(|k| args.flag(k).is_some());
            let admission = if args.flag_bool("admission") || knobs {
                let mut a = config.admission.clone().unwrap_or_default();
                a.slack = args.flag_f64("slack", a.slack)?;
                a.shed_horizon_s = args.flag_f64("shed-horizon", a.shed_horizon_s)?;
                a.retry_after_s = args.flag_f64("retry-after", a.retry_after_s)?;
                a.validate()?;
                Some(a)
            } else {
                None
            };
            let cache = cache_from(&args, config.cache.as_ref())?;
            let server = omni_serve::server::Server::bind(
                &addr,
                config,
                artifacts,
                omni_serve::server::ServeOptions { autoscaler, admission, cache },
            )?;
            server.serve()
        }
        "run" => {
            let mut config = pipeline_from(&args)?;
            // Cache knobs land in the pipeline config: `run_workload`
            // resolves the session's CacheConfig from it.
            if let Some(c) = cache_from(&args, config.cache.as_ref())? {
                config.cache = Some(c);
            }
            let artifacts = Arc::new(Artifacts::load(&Artifacts::default_dir())?);
            let n = args.flag_usize("n", 8)?;
            let rate = args.flag_f64("rate", 0.0)?;
            let seed = args.flag_usize("seed", 1)? as u64;
            let dataset = args.flag("dataset").unwrap_or("ucf101");
            let workload = match dataset {
                "librispeech" => datasets::librispeech(seed, n, rate),
                "food101" => datasets::food101(seed, n, rate),
                "ucf101" => datasets::ucf101(seed, n, rate),
                "seedtts" => datasets::seedtts(seed, n, rate),
                "vbench" => datasets::vbench(seed, n, rate, 20, false),
                "bursty" => datasets::bursty_mixed(seed, n, 2.0),
                "prefill-heavy" => {
                    datasets::prefill_heavy(seed, n, if rate > 0.0 { rate } else { 56.0 })
                }
                "shared-prefix" => datasets::shared_prefix(seed, n, rate, 0.75),
                "branching" => datasets::branching_fanout(seed, n, rate, 20),
                other => bail!("unknown dataset `{other}`"),
            };
            let audio_stage: Option<&'static str> = if config.stage("talker").is_some() {
                Some("talker")
            } else if config.stage("backbone").is_some() {
                Some("backbone")
            } else {
                None
            };
            println!(
                "pipeline={} dataset={} n={} (avg in {:.1} tok, text out {:.1}, audio out {:.1})",
                config.name,
                workload.name,
                workload.len(),
                workload.avg_input_tokens(),
                workload.avg_text_out(),
                workload.avg_audio_out(),
            );
            if args.flag_bool("baseline") {
                let report = omni_serve::baseline::run_monolithic(
                    &artifacts,
                    &config,
                    &workload,
                    &omni_serve::baseline::BaselineOptions {
                        lazy_compile: args.flag_bool("lazy-compile"),
                        no_kv_cache: false,
                    },
                    audio_stage,
                )?;
                print_report(&report);
            } else {
                let deadline = args.flag_f64("deadline", 0.0)?;
                let opts = RunOptions {
                    streaming: !args.flag_bool("no-streaming"),
                    lazy_compile: args.flag_bool("lazy-compile"),
                    realtime_arrivals: rate > 0.0,
                    store_addr: None,
                    deadline_s: (deadline > 0.0).then_some(deadline),
                };
                let orch = Orchestrator::new(config, artifacts, Registry::builtin(), opts)?;
                let summary = orch.run_workload(&workload, audio_stage)?;
                print_report(&summary.report);
                for s in &summary.stages {
                    // Replicated stages report one line per engine
                    // replica; unreplicated output is unchanged.
                    let label = if s.replica == 0 {
                        s.name.clone()
                    } else {
                        format!("{}#r{}", s.name, s.replica)
                    };
                    if let Some(ar) = &s.ar {
                        println!(
                            "stage {:>10}: {} prefill tok, {} decode tok, {} calls, exec {} (marshal {})",
                            label,
                            ar.prefill_tokens,
                            ar.decode_tokens,
                            ar.prefill_calls + ar.decode_calls + ar.scan_calls,
                            fmt::dur(ar.exec_seconds),
                            fmt::dur(ar.marshal_seconds),
                        );
                    }
                    if let Some(sc) = &s.sched {
                        println!(
                            "sched {:>10}: policy {} | admitted {} | passthrough {} | peak queue {} | mean wait {}",
                            label,
                            sc.policy,
                            sc.admitted,
                            sc.passthrough,
                            sc.max_queue_depth,
                            fmt::dur(sc.queue_wait.mean()),
                        );
                    }
                    // Event-core mailbox counters: spurious wakes mean a
                    // park ended with nothing pending (liveness backstop
                    // firing — a hot value flags a missing wake hook).
                    if s.wakeups + s.spurious_wakeups > 0 {
                        println!(
                            "wake  {:>10}: {} wakeups ({} spurious) | parked {}",
                            label,
                            s.wakeups,
                            s.spurious_wakeups,
                            fmt::dur(s.idle_ms / 1e3),
                        );
                    }
                }
            }
            Ok(())
        }
        "bench" => {
            // Artifact-free comparisons: the elastic-allocation harness
            // on the two-stage AR model, and — for `--trace
            // prefill-heavy` — the P/D-disaggregation harness (fused vs
            // split pools at equal GPU budget; same code as the asserted
            // suites in benches/sched_batching.rs and tests/disagg.rs).
            let n = args.flag_usize("n", 48)?;
            let seed = args.flag_usize("seed", 1)? as u64;
            let budget = args.flag_usize("budget", 4)?;
            let trace = args.flag("trace").unwrap_or("bursty");
            if trace == "overload-storm" {
                // CI smoke contract: SLO-aware admission + shedding must
                // beat FIFO-with-deadlines on goodput at EVERY overload
                // multiple for EVERY seed, or this command exits non-zero.
                let lanes = budget.max(1);
                let seeds = args.flag_usize("seeds", 32)? as u64;
                println!(
                    "trace=overload-storm-sim lanes={lanes} seeds={seeds} \
                     (admission+shedding vs FIFO-with-deadlines)"
                );
                for mult in [2.0, 3.0, 5.0] {
                    let mut worst = f64::INFINITY;
                    let mut sum = 0.0;
                    for s in 1..=seeds {
                        let c = omni_serve::scheduler::sim::overload_comparison(s, lanes, mult);
                        let m = c.margin();
                        sum += m;
                        worst = worst.min(m);
                        anyhow::ensure!(
                            m > 0.0,
                            "admission lost to FIFO at {mult}x load, seed {s}: \
                             goodput {:.3} vs {:.3}",
                            c.admission.goodput(),
                            c.fifo.goodput(),
                        );
                    }
                    println!(
                        "  {mult:.0}x offered load: goodput margin mean {:+.3} worst {:+.3}",
                        sum / seeds as f64,
                        worst,
                    );
                }
                println!("admission > fifo goodput confirmed at 2x/3x/5x over {seeds} seeds");
                return Ok(());
            }
            if trace == "shared-prefix" {
                // CI smoke contract: at the same GPU budget the
                // prefix-cached engine must beat the cold engine on BOTH
                // mean TTFT and mean JCT for EVERY seed, or this command
                // exits non-zero.
                let seeds = args.flag_usize("seeds", 32)? as u64;
                println!(
                    "trace=shared-prefix-sim max_batch={budget} seeds={seeds} \
                     (prefix-cached vs cold at equal budget)"
                );
                let (mut worst_ttft, mut worst_jct) = (f64::INFINITY, f64::INFINITY);
                let (mut sum_ttft, mut sum_jct) = (0.0, 0.0);
                let mut skipped = 0u64;
                for s in 1..=seeds {
                    let c = omni_serve::scheduler::sim::prefix_cache_comparison(s, budget);
                    anyhow::ensure!(
                        c.cached.mean_ttft() < c.cold.mean_ttft()
                            && c.cached.mean_jct() < c.cold.mean_jct(),
                        "prefix cache lost to cold at seed {s}: \
                         TTFT {} vs {}, JCT {} vs {}",
                        fmt::dur(c.cached.mean_ttft()),
                        fmt::dur(c.cold.mean_ttft()),
                        fmt::dur(c.cached.mean_jct()),
                        fmt::dur(c.cold.mean_jct()),
                    );
                    worst_ttft = worst_ttft.min(c.ttft_margin());
                    worst_jct = worst_jct.min(c.jct_margin());
                    sum_ttft += c.ttft_margin();
                    sum_jct += c.jct_margin();
                    skipped += c.cached.tokens_skipped;
                }
                println!(
                    "  TTFT margin mean {:+.1}% worst {:+.1}% | JCT margin mean {:+.1}% worst {:+.1}%",
                    100.0 * sum_ttft / seeds as f64,
                    100.0 * worst_ttft,
                    100.0 * sum_jct / seeds as f64,
                    100.0 * worst_jct,
                );
                println!(
                    "  {} prompt tokens attached from cache across {seeds} seeds",
                    skipped,
                );
                println!("cached < cold on TTFT and JCT confirmed over {seeds} seeds");
                return Ok(());
            }
            if trace == "cross-node" {
                // CI smoke contract: at equal hardware (3 nodes x 2
                // GPUs, same replica counts) the transfer-aware cluster
                // placement must beat round-robin on mean JCT for EVERY
                // seed, or this command exits non-zero.
                let seeds = args.flag_usize("seeds", 32)? as u64;
                println!(
                    "trace=cross-node-sim seeds={seeds} \
                     (transfer-aware vs round-robin placement, 3 nodes x 2 gpus)"
                );
                let mut worst = f64::INFINITY;
                let mut sum = 0.0;
                for s in 1..=seeds {
                    let c = omni_serve::scheduler::sim::cross_node_comparison(s);
                    let m = c.jct_margin();
                    anyhow::ensure!(
                        m > 0.0,
                        "transfer-aware placement lost to round-robin at seed {s}: \
                         JCT {} vs {} ({} vs {} cross-node transfers)",
                        fmt::dur(c.transfer_aware.mean_jct()),
                        fmt::dur(c.round_robin.mean_jct()),
                        c.transfer_aware.cross_transfers,
                        c.round_robin.cross_transfers,
                    );
                    sum += m;
                    worst = worst.min(m);
                }
                let c = omni_serve::scheduler::sim::cross_node_comparison(1);
                println!(
                    "  JCT margin mean {:+.1}% worst {:+.1}% | cross-node transfers {} vs {} \
                     | wire time {} vs {} (seed 1)",
                    100.0 * sum / seeds as f64,
                    100.0 * worst,
                    c.transfer_aware.cross_transfers,
                    c.round_robin.cross_transfers,
                    fmt::dur(c.transfer_aware.transfer_s),
                    fmt::dur(c.round_robin.transfer_s),
                );
                println!(
                    "transfer-aware < round-robin on mean JCT confirmed over {seeds} seeds"
                );
                return Ok(());
            }
            if trace == "fractional" {
                // CI smoke contract: at equal hardware (6 devices either
                // way) the packed-fractional layout — encoder + vocoder
                // co-resident on one shared device, third DiT replica on
                // the freed one — must beat whole-device packing on mean
                // JCT for EVERY seed, or this command exits non-zero.
                let seeds = args.flag_usize("seeds", 32)? as u64;
                println!(
                    "trace=branching-fanout-sim seeds={seeds} \
                     (packed-fractional vs whole-device layout, 6 devices)"
                );
                let mut worst = f64::INFINITY;
                let mut sum = 0.0;
                for s in 1..=seeds {
                    let c = omni_serve::scheduler::sim::fractional_comparison(s);
                    anyhow::ensure!(
                        c.fractional.jct.len() == c.whole.jct.len(),
                        "seed {s}: incomplete run ({} vs {} completions)",
                        c.fractional.jct.len(),
                        c.whole.jct.len(),
                    );
                    let m = c.jct_margin();
                    anyhow::ensure!(
                        m > 0.0,
                        "fractional packing lost to whole-device packing at seed {s}: \
                         JCT {} vs {}",
                        fmt::dur(c.fractional.mean_jct()),
                        fmt::dur(c.whole.mean_jct()),
                    );
                    sum += m;
                    worst = worst.min(m);
                }
                let c = omni_serve::scheduler::sim::fractional_comparison(1);
                println!(
                    "  JCT margin mean {:+.1}% worst {:+.1}% | seed 1: fractional {} vs whole {}",
                    100.0 * sum / seeds as f64,
                    100.0 * worst,
                    fmt::dur(c.fractional.mean_jct()),
                    fmt::dur(c.whole.mean_jct()),
                );
                println!("fractional < whole on mean JCT confirmed over {seeds} seeds");
                return Ok(());
            }
            if trace == "bursty-mixed" {
                // The event-core harness on the bursty-mixed trace:
                // `--event-core` is the CI smoke gate (the event-driven
                // executor must beat the bounded-backoff polling baseline
                // on EVERY seed, or this command exits non-zero);
                // `--replay-record` captures one seeded run as an OEVL
                // log that `omni-serve replay` re-drives bit-for-bit.
                let n = args.flag_usize("n", 64)?;
                let lanes = budget.max(1) as u32;
                if args.flag_bool("event-core") {
                    let seeds = args.flag_usize("seeds", 32)? as u64;
                    println!(
                        "trace=bursty-mixed-replay lanes={lanes} n={n} seeds={seeds} \
                         (event-driven core vs bounded-backoff polling)"
                    );
                    let (mut sum_jct, mut worst_jct) = (0.0, f64::INFINITY);
                    let (mut sum_wait, mut worst_wait) = (0.0, f64::INFINITY);
                    for s in 1..=seeds {
                        let (_, ev) = omni_serve::event_core::replay::record(s, n, lanes);
                        let poll = omni_serve::event_core::replay::record_polling(s, n, lanes);
                        anyhow::ensure!(
                            ev.mean_jct_s() <= poll.mean_jct_s(),
                            "event core lost to polling on mean JCT at seed {s}: \
                             {:.6}s vs {:.6}s",
                            ev.mean_jct_s(),
                            poll.mean_jct_s(),
                        );
                        anyhow::ensure!(
                            ev.p95_wait_s() < poll.p95_wait_s(),
                            "event core did not improve p95 queue-wait at seed {s}: \
                             {:.6}s vs {:.6}s",
                            ev.p95_wait_s(),
                            poll.p95_wait_s(),
                        );
                        let mj = (poll.mean_jct_s() - ev.mean_jct_s()) / poll.mean_jct_s();
                        let mw = (poll.p95_wait_s() - ev.p95_wait_s()) / poll.p95_wait_s();
                        sum_jct += mj;
                        worst_jct = worst_jct.min(mj);
                        sum_wait += mw;
                        worst_wait = worst_wait.min(mw);
                    }
                    println!(
                        "  JCT margin mean {:+.2}% worst {:+.2}% | \
                         p95 queue-wait margin mean {:+.2}% worst {:+.2}%",
                        100.0 * sum_jct / seeds as f64,
                        100.0 * worst_jct,
                        100.0 * sum_wait / seeds as f64,
                        100.0 * worst_wait,
                    );
                    println!(
                        "event-core <= polling mean JCT and < p95 queue-wait \
                         confirmed over {seeds} seeds"
                    );
                }
                if args.flag_bool("replay-record") {
                    let path = args.flag("replay-path").unwrap_or("replay.evl");
                    let (log, report) = omni_serve::event_core::replay::record(seed, n, lanes);
                    std::fs::write(path, log.encode())
                        .with_context(|| format!("writing replay log to {path}"))?;
                    println!(
                        "recorded seed={seed} lanes={lanes}: {} events to {path}",
                        log.events.len()
                    );
                    println!("{}", report.line());
                }
                if !args.flag_bool("event-core") && !args.flag_bool("replay-record") {
                    bail!(
                        "--trace bursty-mixed needs --event-core (the CI gate) \
                         and/or --replay-record (capture an OEVL log)"
                    );
                }
                return Ok(());
            }
            if trace == "prefill-heavy" {
                let n = args.flag_usize("n", 64)?;
                let wl = datasets::prefill_heavy(seed, n, 56.0);
                let c = omni_serve::scheduler::sim::simulate_disagg(&wl, budget);
                println!("trace={} n={} budget={budget}", wl.name, wl.len());
                for (label, rep) in [
                    ("fused-b4", &c.fused),
                    ("fused-b8", &c.fused_wide),
                    ("split", &c.split_static),
                    ("split-auto", &c.split_auto),
                ] {
                    let mut jct = rep.jct.clone();
                    println!(
                        "  {:<10} {:<22} mean JCT {:>9} p99 {:>9} mean TTFT {:>9} makespan {:>9}",
                        label,
                        rep.policy,
                        fmt::dur(rep.mean_jct()),
                        fmt::dur(jct.p99()),
                        fmt::dur(rep.mean_ttft()),
                        fmt::dur(rep.makespan_s),
                    );
                }
                println!(
                    "  split_auto scale events: prefill {} up / {} down, decode {} up / {} down (peak {} slots)",
                    c.split_auto.stage_scale_ups[0],
                    c.split_auto.stage_scale_downs[0],
                    c.split_auto.stage_scale_ups[1],
                    c.split_auto.stage_scale_downs[1],
                    c.split_auto.max_slots,
                );
                // CI smoke contract: the disaggregated pools must beat
                // the fused pool at EITHER batch cap, or this command
                // exits non-zero.
                anyhow::ensure!(
                    c.split_static.mean_jct() < c.fused_best_jct()
                        && c.split_static.mean_ttft() < c.fused_best_ttft(),
                    "disaggregated pools did not beat the best fused pool (JCT {} vs {}, TTFT {} vs {})",
                    fmt::dur(c.split_static.mean_jct()),
                    fmt::dur(c.fused_best_jct()),
                    fmt::dur(c.split_static.mean_ttft()),
                    fmt::dur(c.fused_best_ttft()),
                );
                anyhow::ensure!(
                    c.split_auto.mean_jct() < c.fused_best_jct()
                        && c.split_auto.max_slots <= budget,
                    "autoscaled split regressed (JCT {} vs fused {}, peak {} slots, budget {budget})",
                    fmt::dur(c.split_auto.mean_jct()),
                    fmt::dur(c.fused_best_jct()),
                    c.split_auto.max_slots,
                );
                println!("disagg < fused confirmed at budget {budget}");
                return Ok(());
            }
            let wl = match trace {
                "bursty" => datasets::bursty_mixed(seed, n, 2.0),
                "librispeech" => datasets::librispeech(seed, n, 4.0),
                "seedtts" => datasets::seedtts(seed, n, 4.0),
                other => {
                    bail!(
                        "unknown trace `{other}` \
                         (bursty|bursty-mixed|librispeech|seedtts|prefill-heavy|\
                         overload-storm|shared-prefix|cross-node|fractional)"
                    )
                }
            };
            let (statics, auto) = omni_serve::scheduler::sim::elastic_comparison(&wl, budget);
            println!("trace={} n={} budget={budget}", wl.name, wl.len());
            for rep in &statics {
                println!(
                    "  {:<22} mean JCT {:>9} makespan {:>9} gpu-s {:>8.2}",
                    rep.policy,
                    fmt::dur(rep.mean_jct()),
                    fmt::dur(rep.makespan_s),
                    rep.replica_seconds,
                );
            }
            println!(
                "  {:<22} mean JCT {:>9} makespan {:>9} gpu-s {:>8.2} ({} ups, {} downs, peak {} slots)",
                auto.policy,
                fmt::dur(auto.mean_jct()),
                fmt::dur(auto.makespan_s),
                auto.replica_seconds,
                auto.scale_ups,
                auto.scale_downs,
                auto.max_slots,
            );
            Ok(())
        }
        "replay" => {
            // Re-drive a recorded OEVL event log deterministically and
            // print the canonical report line.  A log that carries
            // execution events (a sim capture) must regenerate them
            // bit-for-bit; an arrivals-only log (a serving capture) is
            // re-executed on the deterministic FCFS lane model.
            let path = args
                .positional
                .first()
                .map(String::as_str)
                .or_else(|| args.flag("log"))
                .ok_or_else(|| anyhow::anyhow!("usage: omni-serve replay <log.evl>"))?;
            let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
            let log = omni_serve::event_core::EventLog::decode(&bytes)?;
            println!(
                "decoded {path}: seed={} lanes={} events={}",
                log.seed,
                log.lanes,
                log.events.len(),
            );
            let report = omni_serve::event_core::replay::replay(&log)?;
            println!("{}", report.line());
            Ok(())
        }
        "agent" => {
            // Multi-node mode: host this machine's share of a pipeline.
            // Binds --listen (port 0 picks a free port), prints the
            // bound address for the operator/controller to read, serves
            // one controller session, and exits after a clean drain.
            args.unknown_check(&[
                "node-id",
                "listen",
                "gpus",
                "device-bytes",
                "heartbeat",
                "read-timeout",
            ])?;
            let mut opts = omni_serve::cluster::AgentOptions::new(
                args.require("node-id")?,
                args.require("listen")?,
            );
            opts.gpus = args.flag_usize("gpus", opts.gpus as usize)? as u32;
            opts.device_bytes =
                args.flag_usize("device-bytes", opts.device_bytes as usize)? as u64;
            opts.transport.heartbeat_s =
                args.flag_f64("heartbeat", opts.transport.heartbeat_s)?;
            opts.transport.read_timeout_s =
                args.flag_f64("read-timeout", opts.transport.read_timeout_s)?;
            let report = omni_serve::cluster::run_agent(&opts)?;
            println!(
                "agent {} drained: {} replicas hosted, {} frames moved",
                report.node_id, report.assignments, report.frames_moved,
            );
            for e in &report.edges {
                println!(
                    "  hop {:>14}: {} frames, {} | transfer p50 {:.2} ms p95 {:.2} ms",
                    e.label,
                    e.frames,
                    fmt::bytes(e.bytes as usize),
                    e.p50_ms,
                    e.p95_ms,
                );
            }
            Ok(())
        }
        "graph" => {
            if args.flag_bool("list") {
                for p in presets::all() {
                    println!("{}", p.name);
                }
                return Ok(());
            }
            let config = pipeline_from(&args)?;
            println!("{}", loader::to_json_string(&config));
            Ok(())
        }
        "help" | "" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
}

fn print_report(r: &omni_serve::metrics::RunReport) {
    let mut jct = r.jct.clone();
    let cancelled = if r.cancelled > 0 {
        format!(" cancelled={}", r.cancelled)
    } else {
        String::new()
    };
    // Goodput only means something once requests carry deadlines or the
    // admission controller rejected/shed some of the offered load.
    let goodput = if r.rejected > 0 || r.offered > r.completed + r.cancelled {
        format!(
            " rejected={} goodput={:.3} ({}/{} in-SLO)",
            r.rejected,
            r.goodput(),
            r.in_slo,
            r.offered,
        )
    } else {
        String::new()
    };
    // TPOT is the client-boundary inter-delta latency (empty for runs
    // whose requests streamed at most one delta).
    let tpot = if r.tpot.is_empty() {
        String::new()
    } else {
        format!(
            " | TPOT p50={} p95={}",
            fmt::dur(r.tpot_percentile(50.0)),
            fmt::dur(r.tpot_percentile(95.0)),
        )
    };
    println!(
        "completed={}{}{} wall={} | JCT mean={} p50={} p99={} | TTFT mean={} | first-token mean={}{} | RTF mean={:.3}",
        r.completed,
        cancelled,
        goodput,
        fmt::dur(r.wall_s),
        fmt::dur(r.mean_jct()),
        fmt::dur(jct.p50()),
        fmt::dur(jct.p99()),
        fmt::dur(r.mean_ttft()),
        fmt::dur(r.mean_first_token()),
        tpot,
        if r.rtf.is_empty() { f64::NAN } else { r.mean_rtf() },
    );
    // Cache effectiveness, when any stage did cache lookups this run.
    let cache = r.cache_totals();
    if cache.prefix_hits + cache.prefix_misses + cache.encoder_hits + cache.encoder_misses > 0 {
        println!(
            "  cache: prefix {}/{} hits ({:.1}% | {} evictions) | encoder {}/{} hits ({:.1}%)",
            cache.prefix_hits,
            cache.prefix_hits + cache.prefix_misses,
            100.0 * cache.prefix_hit_rate(),
            cache.evictions,
            cache.encoder_hits,
            cache.encoder_hits + cache.encoder_misses,
            100.0 * cache.encoder_hit_rate(),
        );
    }
    // Per-edge transfer counters, when any edge moved payload frames.
    for e in r.edges.iter().filter(|e| e.frames > 0) {
        println!(
            "  edge  {:>14}: {} frames, {} | transfer p50 {:.2} ms p95 {:.2} ms",
            e.label,
            e.frames,
            fmt::bytes(e.bytes as usize),
            e.p50_ms,
            e.p95_ms,
        );
    }
    let mut stages: Vec<&String> = r.per_stage.keys().collect();
    stages.sort();
    for s in stages {
        // Per-stage queue-wait p50/p95 makes prefill/decode splits
        // observable: a backed-up decode pool shows up here first.
        let waits = if r.sched.contains_key(s.as_str()) {
            format!(
                " | queue-wait p50 {} p95 {}",
                fmt::dur(r.sched_wait_percentile(s, 50.0)),
                fmt::dur(r.sched_wait_percentile(s, 95.0)),
            )
        } else {
            String::new()
        };
        println!(
            "  stage {:>10}: mean residence {} | {} tokens | TPS {:.1}{}",
            s,
            fmt::dur(r.stage_mean_time(s)),
            r.stage_tokens(s),
            r.stage_tps(s),
            waits,
        );
    }
}
