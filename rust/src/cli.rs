//! Hand-rolled CLI argument parsing (no `clap` in the offline registry).
//!
//! Grammar: `omni-serve <command> [--flag[=value] | --flag value | positional]...`

use std::collections::HashMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

/// Flags that never take a value (`--flag value` ambiguity resolution).
pub const BOOL_FLAGS: &[&str] = &[
    "verbose",
    "baseline",
    "no-streaming",
    "lazy-compile",
    "list",
    "help",
    "quiet",
    "autoscale",
    "admission",
    "no-prefix-cache",
    "event-core",
    "replay-record",
];

impl Args {
    /// Parse from an iterator of argument strings (sans argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut out = Args { command, ..Default::default() };
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if !BOOL_FLAGS.contains(&flag)
                    && it.peek().map(|n| !n.starts_with("--")).unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(flag.to_string(), v);
                } else {
                    out.flags.insert(flag.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{v}`")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.flag(name).ok_or_else(|| anyhow::anyhow!("missing required --{name}"))
    }

    pub fn unknown_check(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn commands_flags_positionals() {
        let a = parse("serve --pipeline qwen3-omni --port=8090 --verbose extra");
        assert_eq!(a.command, "serve");
        assert_eq!(a.flag("pipeline"), Some("qwen3-omni"));
        assert_eq!(a.flag("port"), Some("8090"));
        assert!(a.flag_bool("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn typed_flags() {
        let a = parse("run --n 12 --rate 2.5");
        assert_eq!(a.flag_usize("n", 0).unwrap(), 12);
        assert_eq!(a.flag_f64("rate", 0.0).unwrap(), 2.5);
        assert_eq!(a.flag_usize("missing", 7).unwrap(), 7);
        assert!(parse("run --n abc").flag_usize("n", 0).is_err());
    }

    #[test]
    fn autoscale_is_a_bool_flag() {
        // `--autoscale` must not swallow a following positional/value.
        let a = parse("serve --autoscale --gpu-budget 4");
        assert!(a.flag_bool("autoscale"));
        assert_eq!(a.flag_usize("gpu-budget", 0).unwrap(), 4);
        let b = parse("serve --autoscale 8090");
        assert!(b.flag_bool("autoscale"));
        assert_eq!(b.positional, vec!["8090"]);
    }

    #[test]
    fn admission_is_a_bool_flag_with_numeric_companions() {
        let a = parse("serve --admission --slack 1.5 --shed-horizon 2.0");
        assert!(a.flag_bool("admission"));
        assert_eq!(a.flag_f64("slack", 1.0).unwrap(), 1.5);
        assert_eq!(a.flag_f64("shed-horizon", 4.0).unwrap(), 2.0);
    }

    #[test]
    fn no_prefix_cache_is_a_bool_flag() {
        // `--no-prefix-cache` must not swallow the eviction name after it.
        let a = parse("serve --no-prefix-cache --eviction hit_aware --encoder-cache 0");
        assert!(a.flag_bool("no-prefix-cache"));
        assert_eq!(a.flag("eviction"), Some("hit_aware"));
        assert_eq!(a.flag_usize("encoder-cache", 256).unwrap(), 0);
    }

    #[test]
    fn event_core_and_replay_record_are_bool_flags() {
        // `--event-core` / `--replay-record` must not swallow the value
        // that follows (trace name, replay path).
        let a = parse("bench --trace bursty-mixed --event-core --seeds 32");
        assert!(a.flag_bool("event-core"));
        assert_eq!(a.flag("trace"), Some("bursty-mixed"));
        assert_eq!(a.flag_usize("seeds", 0).unwrap(), 32);
        let b = parse("bench --replay-record --replay-path smoke.evl");
        assert!(b.flag_bool("replay-record"));
        assert_eq!(b.flag("replay-path"), Some("smoke.evl"));
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("x --good 1 --bad 2");
        assert!(a.unknown_check(&["good"]).is_err());
        assert!(a.unknown_check(&["good", "bad"]).is_ok());
    }
}
