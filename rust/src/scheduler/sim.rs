//! Deterministic discrete-time model of an AR stage, for evaluating
//! [`BatchPolicy`] implementations without compiled artifacts.
//!
//! The real AR engine is a synchronous state machine: each iteration runs
//! one bucketed executable over the active batch (a prefill chunk per
//! prefilling sequence, one token per decoding sequence) and sequences
//! join/evict at those boundaries.  This module reproduces exactly that
//! timing skeleton with a two-parameter cost model — a fixed per-iteration
//! dispatch cost plus a marginal per-token cost — so policy-level effects
//! (convoy delays under static batching, slot refill under continuous
//! batching, token-budget admission) appear with the right shape while
//! runs stay reproducible to the bit.
//!
//! `benches/sched_batching.rs` drives this model over the bundled trace
//! generators ([`crate::trace::datasets`]); the integration tests pin the
//! headline property (continuous batching beats FIFO mean JCT on the AR
//! traces) so it cannot silently regress.

use super::policy::{BatchPolicy, EngineView, PendingJob};
use crate::event_core::{drive, Driver, SimDriver, Tick, WakeSet};
use crate::trace::Workload;
use crate::util::stats::Samples;

/// One request as the simulated stage sees it.
#[derive(Debug, Clone)]
pub struct SimRequest {
    pub id: u64,
    pub arrival_s: f64,
    /// Prompt tokens to prefill (text + multimodal frames).
    pub prefill_tokens: usize,
    /// Tokens to generate after prefill.
    pub decode_tokens: usize,
}

/// Map a trace workload onto simulated AR requests (prompt = text +
/// encoder frames, generation = the text-stage budget).
pub fn from_workload(wl: &Workload) -> Vec<SimRequest> {
    wl.requests
        .iter()
        .map(|r| SimRequest {
            id: r.id,
            arrival_s: r.arrival_s,
            prefill_tokens: r.total_input_tokens().max(1),
            decode_tokens: r.max_text_tokens.max(1),
        })
        .collect()
}

/// Iteration cost model.  Defaults approximate the CPU-PJRT testbed's
/// decode-step decomposition (dispatch-dominated, weak per-token slope —
/// see `benches/perf_micro.rs`).
#[derive(Debug, Clone)]
pub struct SimCost {
    /// Fixed cost per engine iteration (dispatch, KV marshaling).
    pub base_s: f64,
    /// Marginal cost per token processed in an iteration.
    pub token_s: f64,
    /// Prompt tokens consumed per prefilling sequence per iteration
    /// (chunked prefill).
    pub prefill_chunk: usize,
    /// Charge one `base_s` dispatch PER PHASE present in an iteration
    /// (the real `ArEngine::step` runs the prefill executable and the
    /// decode executable as separate calls, so a fused engine mixing
    /// both phases pays double dispatch).  `false` (default) keeps the
    /// single-dispatch approximation the legacy models were calibrated
    /// with; [`simulate_disagg`] turns it on for every pool it compares,
    /// since phase-dispatch interference is exactly what the P/D split
    /// removes.
    pub per_phase_dispatch: bool,
}

impl Default for SimCost {
    fn default() -> Self {
        Self {
            base_s: 4e-3,
            token_s: 0.25e-3,
            prefill_chunk: crate::engine::ar::PREFILL_CHUNK,
            per_phase_dispatch: false,
        }
    }
}

/// Aggregate results of one simulated run.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub policy: String,
    /// Per-request job completion times (arrival → last token).
    pub jct: Samples,
    pub iterations: u64,
    pub makespan_s: f64,
    /// Mean batch occupancy over iterations (batching effectiveness).
    pub mean_batch: f64,
}

impl SimReport {
    pub fn mean_jct(&self) -> f64 {
        self.jct.mean()
    }
}

struct Active {
    arrival_s: f64,
    prefill_left: usize,
    decode_left: usize,
    /// Constant token commitment (prompt + generation budget), matching
    /// `ArEngine::committed_tokens` — the real engine's admission signal
    /// does not decay as tokens are produced, only on eviction.
    commitment: usize,
}

/// Serve `reqs` through a simulated AR stage under `policy`.
pub fn simulate(
    policy: &mut dyn BatchPolicy,
    max_batch: usize,
    cost: &SimCost,
    reqs: &[SimRequest],
) -> SimReport {
    let mut arrivals: Vec<&SimRequest> = reqs.iter().collect();
    arrivals.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    let mut next_arrival = 0usize;
    let mut queue: Vec<&SimRequest> = Vec::new();
    let mut active: Vec<Active> = Vec::new();

    let mut jct = Samples::new();
    let mut iterations = 0u64;
    let mut occupancy = 0u64;

    // The same tick/event skeleton the live stage loop runs under
    // ([`crate::event_core::drive`]), here against the virtual clock: an
    // idle engine *parks to a deadline* (the next arrival) and the
    // [`SimDriver`] jumps time there, exactly like the old `t = r
    // .arrival_s; continue` arm — one loop-body idiom for both worlds.
    let wake = WakeSet::new();
    let mut sim = SimDriver::new();
    drive(&mut sim, &wake, |drv| {
        let t = drv.now();
        // Arrivals up to the current time.
        while next_arrival < arrivals.len() && arrivals[next_arrival].arrival_s <= t {
            queue.push(arrivals[next_arrival]);
            next_arrival += 1;
        }
        if active.is_empty() && queue.is_empty() {
            return match arrivals.get(next_arrival) {
                // Park until the next request arrives.
                Some(r) => Ok(Tick::Idle(Some(r.arrival_s))),
                None => Ok(Tick::Exit),
            };
        }

        // Admission at the token boundary.
        if !queue.is_empty() {
            let view = EngineView {
                running: active.len(),
                max_batch,
                committed_tokens: active.iter().map(|a| a.commitment).sum(),
                lane_steps: vec![],
            };
            let jobs: Vec<PendingJob> = queue
                .iter()
                .map(|r| PendingJob {
                    req_id: r.id,
                    cost_tokens: r.prefill_tokens + r.decode_tokens,
                })
                .collect();
            let mut n = policy.admit(&jobs, &view).min(queue.len());
            if active.is_empty() && n == 0 {
                // Safety valve: a policy must not stall an empty engine.
                debug_assert!(false, "policy {} stalled an empty engine", policy.name());
                n = 1;
            }
            for r in queue.drain(..n) {
                active.push(Active {
                    arrival_s: r.arrival_s,
                    prefill_left: r.prefill_tokens,
                    decode_left: r.decode_tokens,
                    commitment: r.prefill_tokens + r.decode_tokens,
                });
            }
        }
        if active.is_empty() {
            // Queue non-empty but policy is waiting (cannot happen with an
            // empty engine thanks to the valve above).
            return Ok(Tick::Progress);
        }

        // One engine iteration.
        let mut tokens = 0usize;
        for a in &active {
            tokens += if a.prefill_left > 0 { a.prefill_left.min(cost.prefill_chunk) } else { 1 };
        }
        drv.advance(cost.base_s + cost.token_s * tokens as f64);
        let t = drv.now();
        iterations += 1;
        occupancy += active.len() as u64;

        // Advance sequences; the iteration that finishes a prompt also
        // samples the first token (matching the real prefill path).
        for a in &mut active {
            if a.prefill_left > 0 {
                let consumed = a.prefill_left.min(cost.prefill_chunk);
                a.prefill_left -= consumed;
                if a.prefill_left == 0 {
                    a.decode_left = a.decode_left.saturating_sub(1);
                }
            } else {
                a.decode_left = a.decode_left.saturating_sub(1);
            }
        }
        // Evict at the token boundary.
        active.retain(|a| {
            let done = a.prefill_left == 0 && a.decode_left == 0;
            if done {
                jct.push(t - a.arrival_s);
            }
            !done
        });
        Ok(Tick::Progress)
    })
    .expect("sim loop body never errors");

    SimReport {
        policy: policy.name().to_string(),
        jct,
        iterations,
        makespan_s: sim.now(),
        mean_batch: if iterations > 0 { occupancy as f64 / iterations as f64 } else { 0.0 },
    }
}

/// How the routed edge layer assigns requests to a replicated stage's
/// engines in the sim (mirrors [`crate::config::RoutingKind`] at the
/// request granularity — in the real pipeline per-request stickiness is
/// what the affinity policy guarantees, and round-robin/least-depth
/// route single-item requests identically).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimRouting {
    /// Arrival-order rotation across replicas.
    RoundRobin,
    /// Greedy work balance: each request goes to the replica with the
    /// least total token-work assigned so far (the sim's stand-in for
    /// live queue-depth feedback).
    LeastWork,
    /// `req_id % replicas` — the router's affinity hash.
    Affinity,
}

impl SimRouting {
    pub fn name(self) -> &'static str {
        match self {
            SimRouting::RoundRobin => "round-robin",
            SimRouting::LeastWork => "least-work",
            SimRouting::Affinity => "affinity",
        }
    }
}

/// Serve `reqs` through a stage replicated across `policies.len()`
/// engines (paper §3.3 flexible GPU allocation): the routing policy
/// partitions requests across replicas at arrival, each replica runs the
/// standard single-engine simulation on its share, and the reports merge.
/// With one replica this is exactly [`simulate`].
pub fn simulate_replicated(
    policies: &mut [Box<dyn BatchPolicy>],
    max_batch: usize,
    cost: &SimCost,
    reqs: &[SimRequest],
    routing: SimRouting,
) -> SimReport {
    let n = policies.len();
    assert!(n >= 1, "need at least one replica");
    // Route at arrival, deterministically.
    let mut order: Vec<&SimRequest> = reqs.iter().collect();
    order.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id)));
    let mut parts: Vec<Vec<SimRequest>> = (0..n).map(|_| vec![]).collect();
    let mut assigned_work = vec![0usize; n];
    for (k, r) in order.iter().enumerate() {
        let i = match routing {
            SimRouting::RoundRobin => k % n,
            SimRouting::Affinity => (r.id % n as u64) as usize,
            SimRouting::LeastWork => (0..n)
                .min_by_key(|&i| (assigned_work[i], i))
                .expect("n >= 1"),
        };
        assigned_work[i] += r.prefill_tokens + r.decode_tokens;
        parts[i].push((*r).clone());
    }
    // Each replica is an independent engine over its share.
    let mut jct = Samples::new();
    let mut iterations = 0u64;
    let mut makespan = 0.0f64;
    let mut occupancy = 0.0f64;
    let mut base_policy = String::new();
    for (policy, part) in policies.iter_mut().zip(&parts) {
        let rep = simulate(policy.as_mut(), max_batch, cost, part);
        jct.extend(&rep.jct);
        occupancy += rep.mean_batch * rep.iterations as f64;
        iterations += rep.iterations;
        makespan = makespan.max(rep.makespan_s);
        base_policy = rep.policy;
    }
    SimReport {
        policy: if n == 1 {
            base_policy
        } else {
            format!("{base_policy} x{n} ({})", routing.name())
        },
        jct,
        iterations,
        makespan_s: makespan,
        mean_batch: if iterations > 0 { occupancy / iterations as f64 } else { 0.0 },
    }
}

// ---------------------------------------------------------------------
// Elastic multi-stage model (paper §3 "flexible GPU allocation" under
// live traffic): a pipeline of AR-like stages whose replica counts can
// change mid-run, driven by the same control law as the real
// [`crate::serving`] autoscaler.  Used to evaluate autoscaled vs static
// replica splits without compiled artifacts (`benches/sched_batching.rs`
// and `tests/serving.rs`).
// ---------------------------------------------------------------------

use crate::config::AutoscalerConfig;
use std::collections::VecDeque;

/// Work one request does at one stage of the elastic pipeline model.
#[derive(Debug, Clone, Copy)]
pub struct StageWork {
    pub prefill: usize,
    pub decode: usize,
}

/// One request flowing through the elastic pipeline (stage `i` consumes
/// `work[i]`; the request enters stage `i+1` when stage `i` finishes it).
#[derive(Debug, Clone)]
pub struct ElasticRequest {
    pub id: u64,
    pub arrival_s: f64,
    pub work: Vec<StageWork>,
}

/// One stage of the elastic pipeline model.
#[derive(Debug, Clone, Copy)]
pub struct ElasticStage {
    pub name: &'static str,
    pub max_batch: usize,
}

/// Map an AR trace onto the two-stage Thinker→Talker elastic model:
/// stage 0 prefills the full input and decodes the text budget, stage 1
/// decodes the audio budget (the paper's hot Talker stage).
pub fn two_stage_from_workload(wl: &Workload) -> Vec<ElasticRequest> {
    wl.requests
        .iter()
        .map(|r| ElasticRequest {
            id: r.id,
            arrival_s: r.arrival_s,
            work: vec![
                StageWork {
                    prefill: r.total_input_tokens().max(1),
                    decode: r.max_text_tokens.max(1),
                },
                StageWork { prefill: 0, decode: r.max_audio_tokens.max(1) },
            ],
        })
        .collect()
}

/// How replicas are allocated over the run.
#[derive(Debug, Clone)]
pub enum ElasticAllocation {
    /// Fixed replica count per stage for the whole run (one entry per
    /// stage; their sum is the GPU budget the split spends).
    Static(Vec<usize>),
    /// Elastic: start every stage at `min_replicas` and let the control
    /// law move replicas toward the bottleneck within `gpu_budget`.
    Auto(AutoscalerConfig),
}

/// Results of one elastic run.
#[derive(Debug, Clone)]
pub struct ElasticReport {
    pub policy: String,
    pub jct: Samples,
    /// Time to first decode token per request (arrival → the iteration
    /// that samples token 0) — the latency the P/D split protects.
    pub ttft: Samples,
    pub makespan_s: f64,
    pub scale_ups: usize,
    pub scale_downs: usize,
    /// Scale-ups per stage (pool-level observability: the disagg
    /// acceptance asserts BOTH the prefill and the decode pool scaled).
    pub stage_scale_ups: Vec<usize>,
    /// Scale-downs per stage.
    pub stage_scale_downs: Vec<usize>,
    /// Peak Σ replicas across stages (budget compliance).
    pub max_slots: usize,
    /// ∫ Σ replicas dt — GPU-time actually held over the run.
    pub replica_seconds: f64,
    /// Live replica count per stage at each scale event `(t, counts)`.
    pub timeline: Vec<(f64, Vec<usize>)>,
}

impl ElasticReport {
    pub fn mean_jct(&self) -> f64 {
        self.jct.mean()
    }

    pub fn mean_ttft(&self) -> f64 {
        self.ttft.mean()
    }
}

struct Lane {
    req: usize,
    prefill_left: usize,
    decode_left: usize,
}

struct Rep {
    active: Vec<Lane>,
    busy: bool,
    busy_until: f64,
    draining: bool,
}

impl Rep {
    fn idle() -> Self {
        Self { active: Vec::new(), busy: false, busy_until: 0.0, draining: false }
    }
}

struct StageSim {
    queue: VecDeque<(usize, StageWork)>,
    reps: Vec<Rep>,
    last_scale: f64,
}

/// Serve `reqs` through the elastic pipeline.  Admission is plain
/// slot-filling continuous batching (identical for static and autoscaled
/// runs, so the comparison isolates the *allocation* policy); iteration
/// timing follows [`SimCost`] exactly like [`simulate`].
pub fn simulate_elastic(
    stages: &[ElasticStage],
    cost: &SimCost,
    reqs: &[ElasticRequest],
    alloc: &ElasticAllocation,
) -> ElasticReport {
    let n_stages = stages.len();
    assert!(n_stages >= 1, "need at least one stage");
    for r in reqs {
        assert_eq!(r.work.len(), n_stages, "request work must cover every stage");
    }
    let auto = match alloc {
        ElasticAllocation::Auto(a) => Some(a.clone()),
        ElasticAllocation::Static(_) => None,
    };
    let mut sims: Vec<StageSim> = match alloc {
        ElasticAllocation::Static(counts) => {
            assert_eq!(counts.len(), n_stages);
            counts
                .iter()
                .map(|&c| StageSim {
                    queue: VecDeque::new(),
                    reps: (0..c.max(1)).map(|_| Rep::idle()).collect(),
                    last_scale: f64::NEG_INFINITY,
                })
                .collect()
        }
        ElasticAllocation::Auto(a) => (0..n_stages)
            .map(|_| StageSim {
                queue: VecDeque::new(),
                reps: (0..a.min_replicas).map(|_| Rep::idle()).collect(),
                last_scale: f64::NEG_INFINITY,
            })
            .collect(),
    };

    let mut order: Vec<usize> = (0..reqs.len()).collect();
    order.sort_by(|&a, &b| {
        reqs[a].arrival_s.total_cmp(&reqs[b].arrival_s).then(reqs[a].id.cmp(&reqs[b].id))
    });
    let mut next_arrival = 0usize;
    let mut next_tick = 0.0f64;
    let mut jct = Samples::new();
    let mut ttft = Samples::new();
    let mut first_token_seen = vec![false; reqs.len()];
    let mut scale_ups = 0usize;
    let mut scale_downs = 0usize;
    let mut stage_scale_ups = vec![0usize; n_stages];
    let mut stage_scale_downs = vec![0usize; n_stages];
    let mut replica_seconds = 0.0f64;
    let mut timeline: Vec<(f64, Vec<usize>)> = Vec::new();
    let live_counts = |sims: &[StageSim]| -> Vec<usize> {
        sims.iter().map(|s| s.reps.iter().filter(|r| !r.draining).count()).collect()
    };
    let mut max_slots = sims.iter().map(|s| s.reps.len()).sum::<usize>();

    // The elastic model runs under the same [`crate::event_core::drive`]
    // skeleton as the live stage loop: each tick consumes every event due
    // `now`, then parks to the next event time and the [`SimDriver`]
    // jumps the virtual clock there (the old `now = t_next` assignment,
    // verbatim, so reports stay bit-identical).
    let wake = WakeSet::new();
    let mut sim = SimDriver::new();
    drive(&mut sim, &wake, |drv| {
        let now = drv.now();
        // (a) Arrivals due now enter the first stage's queue.
        while next_arrival < order.len() && reqs[order[next_arrival]].arrival_s <= now {
            let ri = order[next_arrival];
            next_arrival += 1;
            sims[0].queue.push_back((ri, reqs[ri].work[0]));
        }

        // (b) Finish iterations due now: advance lanes, complete requests
        // (into the next stage's queue, or the JCT sample at the exit).
        for si in 0..n_stages {
            let mut forward: Vec<usize> = Vec::new();
            {
                let sim = &mut sims[si];
                for rep in sim.reps.iter_mut() {
                    if !(rep.busy && rep.busy_until <= now) {
                        continue;
                    }
                    rep.busy = false;
                    for l in rep.active.iter_mut() {
                        if l.prefill_left > 0 {
                            let c = l.prefill_left.min(cost.prefill_chunk);
                            l.prefill_left -= c;
                            if l.prefill_left == 0 {
                                // The iteration finishing a prompt samples
                                // the first token (mirrors the engine).
                                l.decode_left = l.decode_left.saturating_sub(1);
                                if !first_token_seen[l.req] {
                                    first_token_seen[l.req] = true;
                                    ttft.push(now - reqs[l.req].arrival_s);
                                }
                            }
                        } else {
                            l.decode_left = l.decode_left.saturating_sub(1);
                            if !first_token_seen[l.req] {
                                first_token_seen[l.req] = true;
                                ttft.push(now - reqs[l.req].arrival_s);
                            }
                        }
                    }
                    rep.active.retain(|l| {
                        let done = l.prefill_left == 0 && l.decode_left == 0;
                        if done {
                            forward.push(l.req);
                        }
                        !done
                    });
                }
            }
            for ri in forward {
                if si + 1 < n_stages {
                    sims[si + 1].queue.push_back((ri, reqs[ri].work[si + 1]));
                } else {
                    jct.push(now - reqs[ri].arrival_s);
                }
            }
        }

        // (c) Autoscaler control ticks due now: scale-downs free budget
        // first, then scale-ups claim it — one replica per stage per
        // tick, mirroring the serving-runtime control law.
        if let Some(a) = &auto {
            while next_tick <= now {
                // Scale down: a stage whose per-replica pending queue is
                // under the threshold and that has a fully idle replica
                // releases it (it retires in step (d) because it is idle).
                for si in 0..n_stages {
                    let live = sims[si].reps.iter().filter(|r| !r.draining).count();
                    let pressure = sims[si].queue.len() as f64 / live.max(1) as f64;
                    if now - sims[si].last_scale < a.cooldown_s
                        || live <= a.min_replicas
                        || pressure >= a.scale_down_queue
                    {
                        continue;
                    }
                    let idle = sims[si]
                        .reps
                        .iter()
                        .position(|r| !r.draining && !r.busy && r.active.is_empty());
                    if let Some(k) = idle {
                        sims[si].reps[k].draining = true;
                        sims[si].last_scale = now;
                        scale_downs += 1;
                        stage_scale_downs[si] += 1;
                        timeline.push((now, live_counts(&sims)));
                    }
                }
                // Slots still held: every replica that is not a
                // draining-idle one about to vanish in step (d).
                let mut slots = sims
                    .iter()
                    .flat_map(|s| s.reps.iter())
                    .filter(|r| !r.draining || r.busy || !r.active.is_empty())
                    .count();
                for si in 0..n_stages {
                    let live = sims[si].reps.iter().filter(|r| !r.draining).count();
                    let pressure = sims[si].queue.len() as f64 / live.max(1) as f64;
                    if now - sims[si].last_scale < a.cooldown_s
                        || live >= a.max_replicas
                        || pressure < a.scale_up_queue
                        || (a.gpu_budget > 0 && slots + 1 > a.gpu_budget)
                    {
                        continue;
                    }
                    sims[si].reps.push(Rep::idle());
                    sims[si].last_scale = now;
                    slots += 1;
                    scale_ups += 1;
                    stage_scale_ups[si] += 1;
                    timeline.push((now, live_counts(&sims)));
                }
                next_tick += a.interval_s;
            }
        }

        // (d)+(e) Retire drained replicas; dispatch idle replicas.
        for si in 0..n_stages {
            let sim = &mut sims[si];
            let max_batch = stages[si].max_batch.max(1);
            let queue = &mut sim.queue;
            let reps = &mut sim.reps;
            let mut k = 0;
            while k < reps.len() {
                if reps[k].busy {
                    k += 1;
                    continue;
                }
                if !reps[k].draining {
                    while reps[k].active.len() < max_batch {
                        let Some((ri, w)) = queue.pop_front() else { break };
                        reps[k].active.push(Lane {
                            req: ri,
                            prefill_left: w.prefill,
                            decode_left: w.decode.max(1),
                        });
                    }
                }
                if reps[k].active.is_empty() {
                    if reps[k].draining {
                        reps.remove(k);
                        continue; // do not advance k: next rep shifted in
                    }
                    k += 1;
                    continue;
                }
                let mut tokens = 0usize;
                let (mut has_prefill, mut has_decode) = (false, false);
                for l in &reps[k].active {
                    if l.prefill_left > 0 {
                        has_prefill = true;
                        tokens += l.prefill_left.min(cost.prefill_chunk);
                    } else {
                        has_decode = true;
                        tokens += 1;
                    }
                }
                let dispatches = if cost.per_phase_dispatch {
                    (has_prefill as usize + has_decode as usize).max(1)
                } else {
                    1
                };
                reps[k].busy = true;
                reps[k].busy_until =
                    now + cost.base_s * dispatches as f64 + cost.token_s * tokens as f64;
                k += 1;
            }
        }
        max_slots = max_slots.max(sims.iter().map(|s| s.reps.len()).sum());

        // (f) Park to the next event, or exit when nothing is left.
        let work_pending = next_arrival < order.len()
            || sims.iter().any(|s| {
                !s.queue.is_empty() || s.reps.iter().any(|r| r.busy || !r.active.is_empty())
            });
        if !work_pending {
            return Ok(Tick::Exit);
        }
        let mut t_next = f64::INFINITY;
        if next_arrival < order.len() {
            t_next = t_next.min(reqs[order[next_arrival]].arrival_s);
        }
        for s in &sims {
            for r in &s.reps {
                if r.busy {
                    t_next = t_next.min(r.busy_until);
                }
            }
        }
        if auto.is_some() {
            t_next = t_next.min(next_tick);
        }
        // Every event at `now` was consumed above, so t_next > now; the
        // epsilon guards against a pathological zero-cost configuration.
        let t_next = if t_next > now { t_next } else { now + 1e-9 };
        let slots: usize = sims.iter().map(|s| s.reps.len()).sum();
        replica_seconds += slots as f64 * (t_next - now);
        Ok(Tick::Idle(Some(t_next)))
    })
    .expect("sim loop body never errors");

    ElasticReport {
        policy: match alloc {
            ElasticAllocation::Static(c) => format!(
                "static {}",
                c.iter().map(|n| n.to_string()).collect::<Vec<_>>().join("+")
            ),
            ElasticAllocation::Auto(a) => format!("autoscaled (budget {})", a.gpu_budget),
        },
        jct,
        ttft,
        makespan_s: sim.now(),
        scale_ups,
        scale_downs,
        stage_scale_ups,
        stage_scale_downs,
        max_slots,
        replica_seconds,
        timeline,
    }
}

/// The autoscaler parameters the elastic-model benchmarks use: a budget
/// of `budget` single-device replicas shared by all stages, aggressive
/// thresholds, and a control interval well under the trace's burst
/// length.  (The real serving runtime defaults are in
/// [`AutoscalerConfig::default`]; these are tuned for the compressed
/// time scale of [`SimCost::default`].)
pub fn bench_autoscaler(budget: usize) -> AutoscalerConfig {
    AutoscalerConfig {
        min_replicas: 1,
        max_replicas: budget.saturating_sub(1).max(1),
        gpu_budget: budget,
        scale_up_queue: 1.0,
        scale_down_queue: 0.25,
        interval_s: 0.02,
        cooldown_s: 0.05,
    }
}

/// The canonical autoscaled-vs-static comparison (the acceptance
/// property of the elastic control plane): map `wl` onto the two-stage
/// Thinker→Talker model, run every static split `(a, budget - a)` of
/// the GPU budget, and the autoscaled allocation under
/// [`bench_autoscaler`].  Shared by `omni-serve bench`,
/// `benches/sched_batching.rs`, and `tests/serving.rs` so the harness
/// cannot drift between them.  Returns `(static_reports, autoscaled)`.
pub fn elastic_comparison(wl: &Workload, budget: usize) -> (Vec<ElasticReport>, ElasticReport) {
    let reqs = two_stage_from_workload(wl);
    let stages = [
        ElasticStage { name: "thinker", max_batch: 4 },
        ElasticStage { name: "talker", max_batch: 4 },
    ];
    let cost = SimCost::default();
    let statics = (1..budget)
        .map(|a| {
            simulate_elastic(
                &stages,
                &cost,
                &reqs,
                &ElasticAllocation::Static(vec![a, budget - a]),
            )
        })
        .collect();
    let auto = simulate_elastic(
        &stages,
        &cost,
        &reqs,
        &ElasticAllocation::Auto(bench_autoscaler(budget)),
    );
    (statics, auto)
}

// ---------------------------------------------------------------------
// Prefill/decode disaggregation model (paper §3.4 + ISSUE 4): the fused
// AR stage vs a prefill pool feeding a decode pool through KV handoffs,
// at the same GPU budget.  The fused baseline convoys decode steps
// behind prefill chunks (an iteration's cost is dispatch + Σ tokens, so
// one prefilling neighbour inflates every decoding sequence's token
// time ~chunk-fold); the split keeps decode iterations token-cheap and
// lets the autoscaler move replicas to whichever phase is the
// bottleneck.  Drives `benches/sched_batching.rs`, `omni-serve bench
// --trace prefill-heavy` (the CI smoke), and `tests/disagg.rs`.
// ---------------------------------------------------------------------

/// Map a workload onto the fused single-stage model (prefill + decode in
/// one engine, exactly [`simulate`]'s timing skeleton).
pub fn fused_from_workload(wl: &Workload) -> Vec<ElasticRequest> {
    wl.requests
        .iter()
        .map(|r| ElasticRequest {
            id: r.id,
            arrival_s: r.arrival_s,
            work: vec![StageWork {
                prefill: r.total_input_tokens().max(1),
                decode: r.max_text_tokens.max(1),
            }],
        })
        .collect()
}

/// Map a workload onto the disaggregated two-stage model: the prefill
/// pool prefills the prompt and samples the first token (decode = 1,
/// matching the real prefill engine, which exports the first token
/// inside the [`crate::kv_transfer::KvHandoff`]); the decode pool
/// continuous-batches the remaining tokens.
pub fn disagg_from_workload(wl: &Workload) -> Vec<ElasticRequest> {
    wl.requests
        .iter()
        .map(|r| ElasticRequest {
            id: r.id,
            arrival_s: r.arrival_s,
            work: vec![
                StageWork { prefill: r.total_input_tokens().max(1), decode: 1 },
                StageWork { prefill: 0, decode: r.max_text_tokens.max(2) - 1 },
            ],
        })
        .collect()
}

/// Fused vs disaggregated at the same GPU budget.
#[derive(Debug, Clone)]
pub struct DisaggComparison {
    /// A fused pool holding the whole budget statically at the preset
    /// batch cap (4) — a single pool gains nothing from scaling.
    pub fused: ElasticReport,
    /// The same fused pool at the wide batch cap (8, the decode pool's):
    /// the split must beat the fused pool at EITHER cap, so the win
    /// certifies disaggregation, not batch-cap tuning.
    pub fused_wide: ElasticReport,
    /// Phase-tuned prefill + decode pools on a fixed even split of the
    /// budget — the headline JCT + TTFT comparison.
    pub split_static: ElasticReport,
    /// The same split pools under the autoscaler control law, each pool
    /// scaling independently within the shared budget.
    pub split_auto: ElasticReport,
}

impl DisaggComparison {
    /// The stronger fused mean JCT across both batch caps (the baseline
    /// every split assertion compares against).
    pub fn fused_best_jct(&self) -> f64 {
        self.fused.mean_jct().min(self.fused_wide.mean_jct())
    }

    /// The stronger fused mean TTFT across both batch caps.
    pub fn fused_best_ttft(&self) -> f64 {
        self.fused.mean_ttft().min(self.fused_wide.mean_ttft())
    }
}

/// Batch caps for the split pools: prefill is compute-bound, so wide
/// batches only inflate per-chunk latency (TTFT); decode is
/// dispatch-bound, so wide batches amortize it.  Per-phase tuning is a
/// disaggregation dividend a fused pool cannot claim — its one cap
/// serves both phases.
const PREFILL_POOL_BATCH: usize = 2;
const DECODE_POOL_BATCH: usize = 8;

/// The canonical P/D-disaggregation comparison (the acceptance property
/// of the kv_transfer subsystem): serve `wl` through fused AR pools of
/// `budget` always-on replicas at BOTH batch caps (the preset's and the
/// decode pool's wide one, so the split is compared against the
/// best-configured fused pool, not a cap-handicapped one), through
/// phase-tuned prefill/decode pools on an even static split, and through
/// the same pools autoscaled within the budget.  Every run pays
/// per-phase dispatch ([`SimCost::per_phase_dispatch`]), which only the
/// fused pool's mixed iterations actually incur.  Shared by
/// `benches/sched_batching.rs`, `omni-serve bench --trace prefill-heavy`
/// (the CI smoke), and `tests/disagg.rs` so the harness cannot drift
/// between them.
pub fn simulate_disagg(wl: &Workload, budget: usize) -> DisaggComparison {
    assert!(budget >= 2, "the split needs at least one replica per pool");
    let cost = SimCost { per_phase_dispatch: true, ..SimCost::default() };
    let fused_reqs = fused_from_workload(wl);
    let fused = simulate_elastic(
        &[ElasticStage { name: "ar-fused", max_batch: 4 }],
        &cost,
        &fused_reqs,
        &ElasticAllocation::Static(vec![budget]),
    );
    let fused_wide = simulate_elastic(
        &[ElasticStage { name: "ar-fused-b8", max_batch: DECODE_POOL_BATCH }],
        &cost,
        &fused_reqs,
        &ElasticAllocation::Static(vec![budget]),
    );
    let split_stages = [
        ElasticStage { name: "prefill", max_batch: PREFILL_POOL_BATCH },
        ElasticStage { name: "decode", max_batch: DECODE_POOL_BATCH },
    ];
    let reqs = disagg_from_workload(wl);
    let split_static = simulate_elastic(
        &split_stages,
        &cost,
        &reqs,
        &ElasticAllocation::Static(vec![budget / 2, budget - budget / 2]),
    );
    let split_auto = simulate_elastic(
        &split_stages,
        &cost,
        &reqs,
        &ElasticAllocation::Auto(bench_autoscaler(budget)),
    );
    DisaggComparison { fused, fused_wide, split_static, split_auto }
}

// ---------------------------------------------------------------------
// SLO-aware overload model (ISSUE 6): admission control + emergency
// shedding vs FIFO-with-deadlines on an overloaded lane pool.  FIFO
// starts work in arrival order and lets deadlines cancel it late, so
// under 2–5x offered load the lanes burn service time on requests that
// can never finish in time; the admission arm projects each arrival's
// completion against its deadline and rejects the doomed ones up front,
// then sheds queued (never in-flight) work earliest-deadline-first when
// the projected backlog exceeds the horizon.  Both arms are judged on
// GOODPUT — completions within SLO over the same offered load — which
// is the metric `serving/admission.rs` optimizes live.  Drives
// `omni-serve bench --trace overload-storm` (the CI gate) and
// `tests/scheduler.rs`.
// ---------------------------------------------------------------------

use crate::config::AdmissionConfig;
use crate::trace::datasets;
use crate::util::Prng;

/// One request as the overload model sees it: a scalar service demand on
/// one lane plus an absolute completion deadline (the request's SLO).
#[derive(Debug, Clone)]
pub struct AdmissionRequest {
    pub id: u64,
    pub arrival_s: f64,
    /// Single-lane service time, derived from the token budgets.
    pub cost_s: f64,
    /// Absolute completion deadline.
    pub deadline_s: f64,
}

/// Map a trace workload onto overload-model requests.  The service
/// demand prices prefill per chunk and every generated token (text +
/// audio + diffusion step) as one iteration, mirroring [`SimCost`]; the
/// SLO slack is drawn deterministically from `Request::seed` in
/// [1.5, 4.0]x the request's own cost plus 50 ms of queueing grace —
/// tight enough that unbounded FIFO queueing misses nearly everything,
/// loose enough that a short queue completes in time.
pub fn admission_from_workload(wl: &Workload, cost: &SimCost) -> Vec<AdmissionRequest> {
    wl.requests
        .iter()
        .map(|r| {
            let prefill = r.total_input_tokens().max(1);
            let decode = (r.max_text_tokens + r.max_audio_tokens + r.diffusion_steps).max(1);
            let iters = prefill.div_ceil(cost.prefill_chunk.max(1)) + decode;
            let cost_s = iters as f64 * cost.base_s + (prefill + decode) as f64 * cost.token_s;
            let mut slo = Prng::new(r.seed ^ 0x510_0DE);
            let slack = 1.5 + 2.5 * slo.f64();
            AdmissionRequest {
                id: r.id,
                arrival_s: r.arrival_s,
                cost_s,
                deadline_s: r.arrival_s + slack * cost_s + 0.05,
            }
        })
        .collect()
}

/// Outcome counters for one overload run.  `offered` is the goodput
/// denominator: both arms are judged on the same offered load, so
/// rejecting work only pays when it lets other work finish in time.
/// Every offered request lands in exactly one terminal bucket:
/// `in_slo + missed + expired + rejected + shed == offered`.
#[derive(Debug, Clone)]
pub struct OverloadReport {
    pub policy: String,
    pub offered: usize,
    /// Rejected at submit time by the admission projection.
    pub rejected: usize,
    /// Shed from the queue (never from a lane) by the backlog horizon.
    pub shed: usize,
    /// Expired waiting in the queue before a lane freed.
    pub expired: usize,
    /// Completed within the SLO — the goodput numerator.
    pub in_slo: usize,
    /// Started on a lane but cancelled at the deadline mid-service.
    pub missed: usize,
    /// Lane-seconds burned on work that was cancelled mid-service.
    pub burned_s: f64,
    /// JCTs of the in-SLO completions.
    pub jct: Samples,
}

impl OverloadReport {
    /// Fraction of OFFERED requests completed within their SLO.
    pub fn goodput(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        self.in_slo as f64 / self.offered as f64
    }
}

enum OverloadPolicy<'a> {
    /// Queue everything; deadlines cancel work late (queued expiries are
    /// free, in-service expiries burn the lane until the deadline).
    FifoDeadline,
    /// Reject at arrival when the projected completion misses the
    /// deadline; shed queued work earliest-deadline-first beyond the
    /// backlog horizon.
    Admission(&'a AdmissionConfig),
}

/// Start queued work on free lanes, in queue order, up to `until`.
fn drain_lanes(
    lane_free: &mut [f64],
    queue: &mut VecDeque<&AdmissionRequest>,
    until: f64,
    rep: &mut OverloadReport,
) {
    while let Some(&head) = queue.front() {
        let lane = lane_free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap();
        let start = lane_free[lane].max(head.arrival_s);
        if start >= until {
            break;
        }
        queue.pop_front();
        if start >= head.deadline_s {
            // Expired waiting: cancelled before any lane time is spent.
            rep.expired += 1;
            continue;
        }
        if start + head.cost_s <= head.deadline_s {
            lane_free[lane] = start + head.cost_s;
            rep.jct.push(start + head.cost_s - head.arrival_s);
            rep.in_slo += 1;
        } else {
            // Doomed: serves until the deadline cancels it mid-flight.
            rep.burned_s += head.deadline_s - start;
            lane_free[lane] = head.deadline_s;
            rep.missed += 1;
        }
    }
}

fn run_overload(reqs: &[AdmissionRequest], lanes: usize, policy: OverloadPolicy) -> OverloadReport {
    assert!(lanes >= 1, "need at least one lane");
    let mut order: Vec<&AdmissionRequest> = reqs.iter().collect();
    order.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id)));
    let mut lane_free = vec![0.0f64; lanes];
    let mut queue: VecDeque<&AdmissionRequest> = VecDeque::new();
    let mut rep = OverloadReport {
        policy: match policy {
            OverloadPolicy::FifoDeadline => "fifo-deadline".into(),
            OverloadPolicy::Admission(_) => "admission".into(),
        },
        offered: reqs.len(),
        rejected: 0,
        shed: 0,
        expired: 0,
        in_slo: 0,
        missed: 0,
        burned_s: 0.0,
        jct: Samples::new(),
    };
    for r in order {
        let now = r.arrival_s;
        drain_lanes(&mut lane_free, &mut queue, now, &mut rep);
        match &policy {
            OverloadPolicy::FifoDeadline => queue.push_back(r),
            OverloadPolicy::Admission(cfg) => {
                // Committed work: queued cost + residual in-service time.
                let backlog: f64 = queue.iter().map(|q| q.cost_s).sum::<f64>()
                    + lane_free.iter().map(|f| (f - now).max(0.0)).sum::<f64>();
                let projected = now + (backlog / lanes as f64 + r.cost_s) * cfg.slack;
                if projected > r.deadline_s {
                    rep.rejected += 1;
                    continue;
                }
                queue.push_back(r);
                // Emergency shedding: queued work ONLY (lanes are never
                // touched), earliest deadline first — the entries least
                // likely to make it anyway.
                let mut backlog = backlog + r.cost_s;
                while backlog / lanes as f64 > cfg.shed_horizon_s && !queue.is_empty() {
                    let victim = queue
                        .iter()
                        .enumerate()
                        .min_by(|a, b| {
                            a.1.deadline_s.total_cmp(&b.1.deadline_s).then(a.1.id.cmp(&b.1.id))
                        })
                        .map(|(i, _)| i)
                        .unwrap();
                    let shed = queue.remove(victim).unwrap();
                    backlog -= shed.cost_s;
                    rep.shed += 1;
                }
            }
        }
    }
    drain_lanes(&mut lane_free, &mut queue, f64::INFINITY, &mut rep);
    rep
}

/// Admission control vs FIFO-with-deadlines on the same offered load.
#[derive(Debug, Clone)]
pub struct AdmissionComparison {
    pub fifo: OverloadReport,
    pub admission: OverloadReport,
}

impl AdmissionComparison {
    /// Goodput margin (admission − FIFO), in fraction-of-offered points.
    pub fn margin(&self) -> f64 {
        self.admission.goodput() - self.fifo.goodput()
    }
}

/// Serve `wl` through both overload arms on a pool of `lanes` lanes.
pub fn simulate_admission(wl: &Workload, lanes: usize, cfg: &AdmissionConfig) -> AdmissionComparison {
    let reqs = admission_from_workload(wl, &SimCost::default());
    AdmissionComparison {
        fifo: run_overload(&reqs, lanes, OverloadPolicy::FifoDeadline),
        admission: run_overload(&reqs, lanes, OverloadPolicy::Admission(cfg)),
    }
}

/// The canonical overload evaluation (the acceptance property of the
/// admission controller): 96 requests of [`datasets::overload_storm`],
/// arrivals rescaled so the offered rate is `load_mult`x the lane
/// pool's service capacity, default admission knobs.  Shared by
/// `omni-serve bench --trace overload-storm` (the CI gate) and
/// `tests/scheduler.rs` so the harness cannot drift between them.
pub fn overload_comparison(seed: u64, lanes: usize, load_mult: f64) -> AdmissionComparison {
    assert!(lanes >= 1 && load_mult > 0.0);
    let mut wl = datasets::overload_storm(seed, 96, 1.0);
    let reqs = admission_from_workload(&wl, &SimCost::default());
    let mean_cost = reqs.iter().map(|r| r.cost_s).sum::<f64>() / reqs.len() as f64;
    // A Poisson process rescales linearly in rate: dividing the 1 req/s
    // arrival times by the target rate leaves every token draw (and so
    // every cost and SLO) untouched.
    let rate = load_mult * lanes as f64 / mean_cost;
    for r in &mut wl.requests {
        r.arrival_s /= rate;
    }
    simulate_admission(&wl, lanes, &AdmissionConfig::default())
}

// ---------------------------------------------------------------------
// Cross-request prefix-cache model (ISSUE 7): the same slot-filling
// continuous-batching engine served twice over a shared-prefix trace —
// once with a global prefix cache (a finished prompt's block-aligned
// token prefix becomes attachable by later requests, exactly the
// chain-hash index in `kv_cache`), once cold — at the same GPU budget.
// The cached arm prefills only from the first miss, so hot repeats trade
// O(prefix) prefill iterations for an O(1) attach; TTFT drops for the
// repeats directly and JCT drops for everyone because the engine stops
// re-spending iterations on tokens it has already computed.  Drives
// `omni-serve bench --trace shared-prefix` (the CI gate),
// `benches/sched_batching.rs`, and `tests/scheduler.rs`.
// ---------------------------------------------------------------------

/// KV block granularity of the model — mirrors the engine's block size
/// (`orchestrator::stage` sizes `BlockManager` with 16-token blocks), so
/// skips land on the same boundaries the real chain-hash index uses.
const PREFIX_BLOCK: usize = 16;

/// One request as the prefix-cache model sees it.
#[derive(Debug, Clone)]
pub struct PrefixRequest {
    pub id: u64,
    pub arrival_s: f64,
    /// Text prompt tokens.  Prefix sharing is computed over these,
    /// block-aligned, exactly like the engine's chain-hash attach.
    pub tokens: Vec<u32>,
    /// Multimodal frames appended after the text prompt.  They sit
    /// behind the unique tail, so the KV prefix cache never covers them
    /// (only the encoder cache dedups the clip itself) — the model
    /// prefills them unconditionally.
    pub mm_tokens: usize,
    pub decode_tokens: usize,
}

/// Map a trace workload onto prefix-model requests.
pub fn prefix_from_workload(wl: &Workload) -> Vec<PrefixRequest> {
    wl.requests
        .iter()
        .map(|r| PrefixRequest {
            id: r.id,
            arrival_s: r.arrival_s,
            tokens: r.prompt_tokens.clone(),
            mm_tokens: r.mm_frames,
            decode_tokens: r.max_text_tokens.max(1),
        })
        .collect()
}

/// Results of one prefix-model run.
#[derive(Debug, Clone)]
pub struct PrefixSimReport {
    pub policy: String,
    pub jct: Samples,
    /// Arrival → first sampled token, the latency the prefix cache cuts.
    pub ttft: Samples,
    pub makespan_s: f64,
    /// Prompt tokens attached from cache instead of re-prefilled.
    pub tokens_skipped: u64,
    /// Requests that attached at least one cached block.
    pub hits: u64,
}

impl PrefixSimReport {
    pub fn mean_jct(&self) -> f64 {
        self.jct.mean()
    }

    pub fn mean_ttft(&self) -> f64 {
        self.ttft.mean()
    }
}

/// Longest block-aligned common token prefix (what a chain-hash lookup
/// can attach: every block hash covers the whole prefix up to it, so a
/// shared prefix is shared block-by-block from the start).
fn block_shared(a: &[u32], b: &[u32]) -> usize {
    let mut n = 0;
    let lim = a.len().min(b.len());
    while n < lim && a[n] == b[n] {
        n += 1;
    }
    (n / PREFIX_BLOCK) * PREFIX_BLOCK
}

/// Serve `reqs` through one slot-filling continuous-batching engine.
/// With `cache` on, a prompt whose prefill completes publishes its token
/// prefix; later admissions attach the longest block-aligned prefix any
/// published prompt shares and prefill only the remainder (at least one
/// token, mirroring the engine, which always recomputes the last
/// position to sample from it).  With `cache` off this is a plain cold
/// engine — the two arms differ ONLY in skipped prefill work.
pub fn simulate_prefix_cache(
    reqs: &[PrefixRequest],
    max_batch: usize,
    cost: &SimCost,
    cache: bool,
) -> PrefixSimReport {
    assert!(max_batch >= 1);
    struct Lane<'a> {
        req: &'a PrefixRequest,
        prefill_left: usize,
        decode_left: usize,
    }
    let mut order: Vec<&PrefixRequest> = reqs.iter().collect();
    order.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id)));
    let mut next_arrival = 0usize;
    let mut queue: VecDeque<&PrefixRequest> = VecDeque::new();
    let mut active: Vec<Lane> = Vec::new();
    let mut resident: Vec<&[u32]> = Vec::new();

    let mut t = 0.0f64;
    let mut jct = Samples::new();
    let mut ttft = Samples::new();
    let mut tokens_skipped = 0u64;
    let mut hits = 0u64;

    loop {
        while next_arrival < order.len() && order[next_arrival].arrival_s <= t {
            queue.push_back(order[next_arrival]);
            next_arrival += 1;
        }
        if active.is_empty() && queue.is_empty() {
            match order.get(next_arrival) {
                Some(r) => {
                    t = r.arrival_s;
                    continue;
                }
                None => break,
            }
        }

        // Slot-filling admission; cached blocks attach at admission.
        while active.len() < max_batch {
            let Some(r) = queue.pop_front() else { break };
            let total = r.tokens.len() + r.mm_tokens;
            let skip = if cache {
                resident.iter().map(|p| block_shared(&r.tokens, p)).max().unwrap_or(0)
            } else {
                0
            };
            let skip = skip.min(total.saturating_sub(1));
            if skip > 0 {
                hits += 1;
                tokens_skipped += skip as u64;
            }
            active.push(Lane {
                req: r,
                prefill_left: total.max(1) - skip,
                decode_left: r.decode_tokens.max(1),
            });
        }

        // One engine iteration (same timing skeleton as `simulate`).
        let mut tokens = 0usize;
        for l in &active {
            tokens += if l.prefill_left > 0 { l.prefill_left.min(cost.prefill_chunk) } else { 1 };
        }
        t += cost.base_s + cost.token_s * tokens as f64;
        for l in &mut active {
            if l.prefill_left > 0 {
                let c = l.prefill_left.min(cost.prefill_chunk);
                l.prefill_left -= c;
                if l.prefill_left == 0 {
                    // The iteration finishing a prompt samples the first
                    // token and publishes the prefix for later requests.
                    l.decode_left = l.decode_left.saturating_sub(1);
                    ttft.push(t - l.req.arrival_s);
                    if cache {
                        resident.push(&l.req.tokens);
                    }
                }
            } else {
                l.decode_left = l.decode_left.saturating_sub(1);
            }
        }
        active.retain(|l| {
            let done = l.prefill_left == 0 && l.decode_left == 0;
            if done {
                jct.push(t - l.req.arrival_s);
            }
            !done
        });
    }

    PrefixSimReport {
        policy: if cache { "prefix-cached".into() } else { "cold".into() },
        jct,
        ttft,
        makespan_s: t,
        tokens_skipped,
        hits,
    }
}

/// Cached vs cold on the same engine at the same GPU budget.
#[derive(Debug, Clone)]
pub struct PrefixCacheComparison {
    pub cached: PrefixSimReport,
    pub cold: PrefixSimReport,
}

impl PrefixCacheComparison {
    /// Relative mean-TTFT win of the cached arm (positive = cached wins).
    pub fn ttft_margin(&self) -> f64 {
        (self.cold.mean_ttft() - self.cached.mean_ttft()) / self.cold.mean_ttft()
    }

    /// Relative mean-JCT win of the cached arm.
    pub fn jct_margin(&self) -> f64 {
        (self.cold.mean_jct() - self.cached.mean_jct()) / self.cold.mean_jct()
    }
}

/// The canonical prefix-cache evaluation (the acceptance property of the
/// global prefix cache): 64 requests of [`datasets::shared_prefix`] at
/// 24 req/s with a 0.75 hot fraction, served cached and cold through the
/// same `max_batch`-slot engine.  Shared by `omni-serve bench --trace
/// shared-prefix` (the CI gate), `benches/sched_batching.rs`, and
/// `tests/scheduler.rs` so the harness cannot drift between them.
pub fn prefix_cache_comparison(seed: u64, max_batch: usize) -> PrefixCacheComparison {
    let wl = datasets::shared_prefix(seed, 64, 24.0, 0.75);
    let reqs = prefix_from_workload(&wl);
    let cost = SimCost::default();
    PrefixCacheComparison {
        cached: simulate_prefix_cache(&reqs, max_batch, &cost, true),
        cold: simulate_prefix_cache(&reqs, max_batch, &cost, false),
    }
}

// ---------------------------------------------------------------------
// Cross-node placement model (ISSUE 8): the same replicated stage chain
// served under two node placements — the cluster allocator's
// transfer-aware co-location vs naive round-robin — at the same
// hardware.  Every stage replica is homed on a node by the REAL
// placement engine ([`crate::cluster::placement::place`]); a request
// hopping between replicas on different nodes pays the link (latency +
// bytes/bandwidth) before it may enter the next stage's queue, and
// node-local hops are free.  Round-robin misaligns every prefill→decode
// pair, so every request's multi-MB KV handoff crosses a node; the
// transfer-aware plan keeps those pairs node-local and routes only the
// KB-sized vocoder hop across.  Drives `omni-serve bench --trace
// cross-node` (the CI gate), `benches/sched_batching.rs`, and
// `tests/scheduler.rs`.
// ---------------------------------------------------------------------

use crate::cluster::placement::{place, ClusterPlan, EdgeDemand, StageDemand};
use crate::config::{ClusterConfig, NodeSpec, PlacementPolicy};
use crate::device::DEFAULT_DEVICE_BYTES;

/// One stage of the placed pipeline: a batch cap and the node hosting
/// each replica (index `r` serves requests with `id % replicas == r`,
/// the router's affinity hash).
#[derive(Debug, Clone)]
pub struct PlacedStage {
    pub name: &'static str,
    pub max_batch: usize,
    pub replica_nodes: Vec<usize>,
}

/// One request flowing through the placed pipeline: per-stage work plus
/// the bytes each inter-stage hop moves for THIS request (`hop_bytes[i]`
/// = stage `i` → stage `i+1`, e.g. the KV handoff scales with the
/// request's prompt length).
#[derive(Debug, Clone)]
pub struct PlacedRequest {
    pub id: u64,
    pub arrival_s: f64,
    pub work: Vec<StageWork>,
    pub hop_bytes: Vec<f64>,
}

/// Results of one placed run.
#[derive(Debug, Clone)]
pub struct PlacedReport {
    pub policy: String,
    pub jct: Samples,
    pub makespan_s: f64,
    /// Hops that crossed a node boundary (and so paid the link).
    pub cross_transfers: u64,
    /// Total seconds spent on the wire.
    pub transfer_s: f64,
}

impl PlacedReport {
    pub fn mean_jct(&self) -> f64 {
        self.jct.mean()
    }
}

/// Serve `reqs` through a replicated stage chain under a node placement.
/// `link` is `(bytes_per_s, latency_s)` — [`ClusterConfig::link`]'s
/// shape.  Identical to the elastic model's static timing skeleton
/// except for the transfer delay: a finished request whose next replica
/// lives on another node re-enters the pipeline only after
/// `latency + bytes/bandwidth`.
pub fn simulate_placed(
    stages: &[PlacedStage],
    cost: &SimCost,
    link: (f64, f64),
    reqs: &[PlacedRequest],
) -> PlacedReport {
    let n_stages = stages.len();
    assert!(n_stages >= 1, "need at least one stage");
    let (bw, lat) = link;
    assert!(bw > 0.0 && lat >= 0.0, "invalid link");
    for r in reqs {
        assert_eq!(r.work.len(), n_stages, "work must cover every stage");
        assert_eq!(r.hop_bytes.len(), n_stages - 1, "one hop per edge");
    }
    for s in stages {
        assert!(!s.replica_nodes.is_empty(), "stage `{}` has no replicas", s.name);
    }
    struct PLane {
        req: usize,
        prefill_left: usize,
        decode_left: usize,
    }
    struct PRep {
        active: Vec<PLane>,
        busy: bool,
        busy_until: f64,
    }
    let mut queues: Vec<Vec<VecDeque<usize>>> =
        stages.iter().map(|s| (0..s.replica_nodes.len()).map(|_| VecDeque::new()).collect()).collect();
    let mut reps: Vec<Vec<PRep>> = stages
        .iter()
        .map(|s| {
            (0..s.replica_nodes.len())
                .map(|_| PRep { active: Vec::new(), busy: false, busy_until: 0.0 })
                .collect()
        })
        .collect();
    let mut order: Vec<usize> = (0..reqs.len()).collect();
    order.sort_by(|&a, &b| {
        reqs[a].arrival_s.total_cmp(&reqs[b].arrival_s).then(reqs[a].id.cmp(&reqs[b].id))
    });
    let mut next_arrival = 0usize;
    // Requests on the wire: `(ready_s, stage, replica, req)` in send
    // order (delivery at equal times follows send order — deterministic).
    let mut pending: Vec<(f64, usize, usize, usize)> = Vec::new();
    let mut now = 0.0f64;
    let mut jct = Samples::new();
    let mut cross_transfers = 0u64;
    let mut transfer_s = 0.0f64;

    loop {
        // (a) Arrivals due now enter their affinity replica's queue.
        while next_arrival < order.len() && reqs[order[next_arrival]].arrival_s <= now {
            let ri = order[next_arrival];
            next_arrival += 1;
            let r = (reqs[ri].id % stages[0].replica_nodes.len() as u64) as usize;
            queues[0][r].push_back(ri);
        }
        // (b) Transfers that have landed enter their replica's queue.
        pending.retain(|&(ready, si, r, ri)| {
            if ready <= now {
                queues[si][r].push_back(ri);
                false
            } else {
                true
            }
        });

        // (c) Finish iterations due now; forward finished requests over
        // the (possibly cross-node) hop to the next stage.
        for si in 0..n_stages {
            for (k, rep) in reps[si].iter_mut().enumerate() {
                if !(rep.busy && rep.busy_until <= now) {
                    continue;
                }
                rep.busy = false;
                let mut forward: Vec<usize> = Vec::new();
                for l in rep.active.iter_mut() {
                    if l.prefill_left > 0 {
                        let c = l.prefill_left.min(cost.prefill_chunk);
                        l.prefill_left -= c;
                        if l.prefill_left == 0 {
                            l.decode_left = l.decode_left.saturating_sub(1);
                        }
                    } else {
                        l.decode_left = l.decode_left.saturating_sub(1);
                    }
                }
                rep.active.retain(|l| {
                    let done = l.prefill_left == 0 && l.decode_left == 0;
                    if done {
                        forward.push(l.req);
                    }
                    !done
                });
                for ri in forward {
                    if si + 1 < n_stages {
                        let to_r =
                            (reqs[ri].id % stages[si + 1].replica_nodes.len() as u64) as usize;
                        let from_node = stages[si].replica_nodes[k];
                        let to_node = stages[si + 1].replica_nodes[to_r];
                        if from_node == to_node {
                            queues[si + 1][to_r].push_back(ri);
                        } else {
                            let delay = lat + reqs[ri].hop_bytes[si] / bw;
                            cross_transfers += 1;
                            transfer_s += delay;
                            pending.push((now + delay, si + 1, to_r, ri));
                        }
                    } else {
                        jct.push(now - reqs[ri].arrival_s);
                    }
                }
            }
        }

        // (d) Dispatch idle replicas with slot-filling admission.
        for si in 0..n_stages {
            let max_batch = stages[si].max_batch.max(1);
            for (k, rep) in reps[si].iter_mut().enumerate() {
                if rep.busy {
                    continue;
                }
                while rep.active.len() < max_batch {
                    let Some(ri) = queues[si][k].pop_front() else { break };
                    let w = reqs[ri].work[si];
                    rep.active.push(PLane {
                        req: ri,
                        prefill_left: w.prefill,
                        decode_left: w.decode.max(1),
                    });
                }
                if rep.active.is_empty() {
                    continue;
                }
                let mut tokens = 0usize;
                for l in &rep.active {
                    tokens +=
                        if l.prefill_left > 0 { l.prefill_left.min(cost.prefill_chunk) } else { 1 };
                }
                rep.busy = true;
                rep.busy_until = now + cost.base_s + cost.token_s * tokens as f64;
            }
        }

        // (e) Advance to the next event, or stop when nothing is left.
        let work_pending = next_arrival < order.len()
            || !pending.is_empty()
            || queues.iter().any(|sq| sq.iter().any(|q| !q.is_empty()))
            || reps.iter().any(|sr| sr.iter().any(|r| r.busy || !r.active.is_empty()));
        if !work_pending {
            break;
        }
        let mut t_next = f64::INFINITY;
        if next_arrival < order.len() {
            t_next = t_next.min(reqs[order[next_arrival]].arrival_s);
        }
        for sr in &reps {
            for r in sr {
                if r.busy {
                    t_next = t_next.min(r.busy_until);
                }
            }
        }
        for &(ready, ..) in &pending {
            t_next = t_next.min(ready);
        }
        now = if t_next > now { t_next } else { now + 1e-9 };
    }

    PlacedReport {
        policy: String::new(),
        jct,
        makespan_s: now,
        cross_transfers,
        transfer_s,
    }
}

/// KV bytes one prompt token's cache occupies on the wire (fp16 KV for
/// the scaled testbed models — what the prefill→decode handoff moves).
pub const KV_TOKEN_BYTES: f64 = (256 * 1024) as f64;
/// Bytes of one decode→vocoder handoff (codec tokens + metadata).
pub const VOC_HANDOFF_BYTES: f64 = (8 * 1024) as f64;

/// Transfer-aware vs round-robin placement at equal hardware.
#[derive(Debug, Clone)]
pub struct CrossNodeComparison {
    pub transfer_aware: PlacedReport,
    pub round_robin: PlacedReport,
    pub aware_plan: ClusterPlan,
    pub rr_plan: ClusterPlan,
}

impl CrossNodeComparison {
    /// Relative mean-JCT win of the transfer-aware arm (positive =
    /// transfer-aware wins).
    pub fn jct_margin(&self) -> f64 {
        (self.round_robin.mean_jct() - self.transfer_aware.mean_jct())
            / self.round_robin.mean_jct()
    }
}

/// The canonical cross-node evaluation (the acceptance property of the
/// cluster allocator): 48 requests of [`datasets::prefill_heavy`] at
/// 6 req/s through a prefill(x2) → decode(x2) → vocoder(x2) chain on
/// 3 nodes x 2 GPUs, replica weights sized so each node holds exactly
/// two replicas — both placements fill the same hardware and differ
/// ONLY in who sits with whom.  Placements come from the REAL cluster
/// allocator; the link is [`ClusterConfig::default`]'s 10 Gbit/s + 2 ms.
/// Shared by `omni-serve bench --trace cross-node` (the CI gate),
/// `benches/sched_batching.rs`, and `tests/scheduler.rs` so the harness
/// cannot drift between them.  (Python-mirror validation: the
/// transfer-aware arm wins mean JCT on ALL 32 seeds with margins in
/// [6.6%, 8.4%], mean 7.3%, at this operating point.)
pub fn cross_node_comparison(seed: u64) -> CrossNodeComparison {
    let wl = datasets::prefill_heavy(seed, 48, 6.0);
    let nodes: Vec<NodeSpec> = (0..3)
        .map(|i| NodeSpec { id: format!("n{i}"), gpus: 2, device_bytes: DEFAULT_DEVICE_BYTES })
        .collect();
    // One replica's weights fill 3/4 of a device: one replica per GPU,
    // two per node, six slots for six replicas — a full cluster.
    let bytes = 3 * DEFAULT_DEVICE_BYTES / 4;
    let demands: Vec<StageDemand> = ["prefill", "decode", "vocoder"]
        .iter()
        .map(|s| StageDemand {
            stage: s.to_string(),
            replicas: 2,
            tp: 1,
            bytes,
            compute_milli: crate::gpu_share::DEVICE_MILLI,
        })
        .collect();
    let mean_kv = wl
        .requests
        .iter()
        .map(|r| r.total_input_tokens() as f64)
        .sum::<f64>()
        / wl.requests.len() as f64
        * KV_TOKEN_BYTES;
    let edges = vec![
        EdgeDemand { from: "prefill".into(), to: "decode".into(), bytes_per_request: mean_kv },
        EdgeDemand { from: "decode".into(), to: "vocoder".into(), bytes_per_request: VOC_HANDOFF_BYTES },
    ];
    let aware_plan = place(&nodes, &demands, &edges, PlacementPolicy::TransferAware)
        .expect("the aware placement fits by construction");
    let rr_plan = place(&nodes, &demands, &edges, PlacementPolicy::RoundRobin)
        .expect("the round-robin placement fits by construction");

    let reqs: Vec<PlacedRequest> = wl
        .requests
        .iter()
        .map(|r| {
            let input = r.total_input_tokens().max(1);
            let out = r.max_text_tokens;
            PlacedRequest {
                id: r.id,
                arrival_s: r.arrival_s,
                work: vec![
                    // The disagg split: prefill samples the first token,
                    // decode continuous-batches the rest, the vocoder
                    // synthesizes one frame per four text tokens.
                    StageWork { prefill: input, decode: 1 },
                    StageWork { prefill: 0, decode: out.max(2) - 1 },
                    StageWork { prefill: 0, decode: (out / 4).max(1) },
                ],
                hop_bytes: vec![input as f64 * KV_TOKEN_BYTES, VOC_HANDOFF_BYTES],
            }
        })
        .collect();
    let link = ClusterConfig::default().link();
    let cost = SimCost::default();
    let stages_for = |plan: &ClusterPlan| -> Vec<PlacedStage> {
        let nodes_of = |stage: &str| -> Vec<usize> {
            (0..2).map(|r| plan.node_of(stage, r).expect("placed")).collect()
        };
        vec![
            PlacedStage { name: "prefill", max_batch: 2, replica_nodes: nodes_of("prefill") },
            PlacedStage { name: "decode", max_batch: 8, replica_nodes: nodes_of("decode") },
            PlacedStage { name: "vocoder", max_batch: 4, replica_nodes: nodes_of("vocoder") },
        ]
    };
    let mut transfer_aware = simulate_placed(&stages_for(&aware_plan), &cost, link, &reqs);
    transfer_aware.policy = "transfer-aware".into();
    let mut round_robin = simulate_placed(&stages_for(&rr_plan), &cost, link, &reqs);
    round_robin.policy = "round-robin".into();
    CrossNodeComparison { transfer_aware, round_robin, aware_plan, rr_plan }
}

// ---------------------------------------------------------------------
// Fractional GPU sharing (ISSUE 9).  A branching any-to-any pipeline —
// one prompt fans out after the shared thinker into a DiT image arm and
// a talker→vocoder speech arm — has two tiny stages (encoder, vocoder)
// that waste most of a whole device each.  Carving them into fractional
// slots co-resident on ONE device frees a whole device for a third DiT
// replica, turning the contended image arm from a 2-server into a
// 3-server pool at identical hardware.  `fractional_comparison` serves
// the same trace through both layouts; `tests/scheduler.rs`,
// `benches/sched_batching.rs`, and `omni-serve bench --trace fractional`
// (the CI gate) all assert the packed-fractional arm wins mean JCT on
// every seed.
// ---------------------------------------------------------------------

use crate::device::{DeviceId, DevicePool};
use crate::gpu_share::{DeviceShare, FracSlot, MilliLedger, DEVICE_MILLI};

/// One stage of the branching fractional pipeline.
#[derive(Debug, Clone)]
pub struct FracStage {
    pub name: &'static str,
    pub max_batch: usize,
    /// Per-replica compute share in milli-GPUs (one entry per replica).
    /// A 300-milli replica runs every iteration at 0.3x device speed —
    /// its guaranteed WRR share, conservatively ignoring the
    /// work-conserving boost an idle co-resident would grant.
    pub replica_milli: Vec<u32>,
    /// Downstream stage indices.  Two or more = a fan-out (a finished
    /// request forks into EVERY successor); empty = a branch exit.
    pub next: Vec<usize>,
}

/// One request through the branching pipeline (stage `i` consumes
/// `work[i]`; a fan-out duplicates the request into each arm and the
/// request completes when its LAST branch exit finishes).
#[derive(Debug, Clone)]
pub struct FracRequest {
    pub id: u64,
    pub arrival_s: f64,
    pub work: Vec<StageWork>,
}

/// Results of one fractional run.
#[derive(Debug, Clone)]
pub struct FracReport {
    pub label: String,
    /// Per-request completion times (arrival → last branch done).
    pub jct: Samples,
    pub makespan_s: f64,
}

impl FracReport {
    pub fn mean_jct(&self) -> f64 {
        self.jct.mean()
    }
}

/// Serve `reqs` through a branching stage tree where replicas may hold
/// fractional compute shares.  The timing skeleton is
/// [`simulate_placed`]'s (affinity routing, slot-filling admission,
/// chunked prefill) with two changes: an iteration on an `m`-milli
/// replica costs `(base + token_s * tokens) / (m / 1000)`, and a stage
/// with several successors forks each finished request into all of them,
/// completing the request only when every branch exit has delivered
/// (per-branch completion semantics).  Stage 0 must be the single entry
/// and the successor lists must form a tree.
pub fn simulate_fractional(
    stages: &[FracStage],
    cost: &SimCost,
    reqs: &[FracRequest],
) -> FracReport {
    let n_stages = stages.len();
    assert!(n_stages >= 1, "need at least one stage");
    for r in reqs {
        assert_eq!(r.work.len(), n_stages, "work must cover every stage");
    }
    let mut indeg = vec![0usize; n_stages];
    for s in stages {
        assert!(!s.replica_milli.is_empty(), "stage `{}` has no replicas", s.name);
        for m in &s.replica_milli {
            assert!((1..=DEVICE_MILLI).contains(m), "stage `{}`: bad milli {m}", s.name);
        }
        for &t in &s.next {
            assert!(t < n_stages, "stage `{}`: successor {t} out of range", s.name);
            indeg[t] += 1;
        }
    }
    assert_eq!(indeg[0], 0, "stage 0 must be the entry");
    assert!(indeg.iter().skip(1).all(|&d| d == 1), "successors must form a fan-out tree");
    let n_exits = stages.iter().filter(|s| s.next.is_empty()).count();

    struct FLane {
        req: usize,
        prefill_left: usize,
        decode_left: usize,
    }
    struct FRep {
        speed: f64,
        active: Vec<FLane>,
        busy: bool,
        busy_until: f64,
    }
    let mut queues: Vec<Vec<VecDeque<usize>>> = stages
        .iter()
        .map(|s| (0..s.replica_milli.len()).map(|_| VecDeque::new()).collect())
        .collect();
    let mut reps: Vec<Vec<FRep>> = stages
        .iter()
        .map(|s| {
            s.replica_milli
                .iter()
                .map(|&m| FRep {
                    speed: f64::from(m) / f64::from(DEVICE_MILLI),
                    active: Vec::new(),
                    busy: false,
                    busy_until: 0.0,
                })
                .collect()
        })
        .collect();
    let mut order: Vec<usize> = (0..reqs.len()).collect();
    order.sort_by(|&a, &b| {
        reqs[a].arrival_s.total_cmp(&reqs[b].arrival_s).then(reqs[a].id.cmp(&reqs[b].id))
    });
    let mut next_arrival = 0usize;
    let mut exits_left = vec![n_exits; reqs.len()];
    let mut now = 0.0f64;
    let mut jct = Samples::new();

    loop {
        // (a) Arrivals due now enter their affinity replica's queue.
        while next_arrival < order.len() && reqs[order[next_arrival]].arrival_s <= now {
            let ri = order[next_arrival];
            next_arrival += 1;
            let r = (reqs[ri].id % stages[0].replica_milli.len() as u64) as usize;
            queues[0][r].push_back(ri);
        }

        // (b) Finish iterations due now; fork finished requests into
        // every successor arm, or retire a branch at its exit.
        for si in 0..n_stages {
            for rep in reps[si].iter_mut() {
                if !(rep.busy && rep.busy_until <= now) {
                    continue;
                }
                rep.busy = false;
                let mut forward: Vec<usize> = Vec::new();
                for l in rep.active.iter_mut() {
                    if l.prefill_left > 0 {
                        let c = l.prefill_left.min(cost.prefill_chunk);
                        l.prefill_left -= c;
                        if l.prefill_left == 0 {
                            l.decode_left = l.decode_left.saturating_sub(1);
                        }
                    } else {
                        l.decode_left = l.decode_left.saturating_sub(1);
                    }
                }
                rep.active.retain(|l| {
                    let done = l.prefill_left == 0 && l.decode_left == 0;
                    if done {
                        forward.push(l.req);
                    }
                    !done
                });
                for ri in forward {
                    if stages[si].next.is_empty() {
                        exits_left[ri] -= 1;
                        if exits_left[ri] == 0 {
                            jct.push(now - reqs[ri].arrival_s);
                        }
                    } else {
                        for &ti in &stages[si].next {
                            let to_r =
                                (reqs[ri].id % stages[ti].replica_milli.len() as u64) as usize;
                            queues[ti][to_r].push_back(ri);
                        }
                    }
                }
            }
        }

        // (c) Dispatch idle replicas with slot-filling admission; the
        // iteration slows by the replica's guaranteed share.
        for si in 0..n_stages {
            let max_batch = stages[si].max_batch.max(1);
            for (k, rep) in reps[si].iter_mut().enumerate() {
                if rep.busy {
                    continue;
                }
                while rep.active.len() < max_batch {
                    let Some(ri) = queues[si][k].pop_front() else { break };
                    let w = reqs[ri].work[si];
                    rep.active.push(FLane {
                        req: ri,
                        prefill_left: w.prefill,
                        decode_left: w.decode.max(1),
                    });
                }
                if rep.active.is_empty() {
                    continue;
                }
                let mut tokens = 0usize;
                for l in &rep.active {
                    tokens +=
                        if l.prefill_left > 0 { l.prefill_left.min(cost.prefill_chunk) } else { 1 };
                }
                rep.busy = true;
                rep.busy_until = now + (cost.base_s + cost.token_s * tokens as f64) / rep.speed;
            }
        }

        // (d) Advance to the next event, or stop when nothing is left.
        let work_pending = next_arrival < order.len()
            || queues.iter().any(|sq| sq.iter().any(|q| !q.is_empty()))
            || reps.iter().any(|sr| sr.iter().any(|r| r.busy || !r.active.is_empty()));
        if !work_pending {
            break;
        }
        let mut t_next = f64::INFINITY;
        if next_arrival < order.len() {
            t_next = t_next.min(reqs[order[next_arrival]].arrival_s);
        }
        for sr in &reps {
            for r in sr {
                if r.busy {
                    t_next = t_next.min(r.busy_until);
                }
            }
        }
        now = if t_next > now { t_next } else { now + 1e-9 };
    }

    FracReport { label: String::new(), jct, makespan_s: now }
}

/// Compute share of each co-resident fraction in the canonical layout.
pub const FRAC_SLOT_MILLI: u32 = 300;
/// Sim iterations of DiT work per diffusion step (a step is several
/// model dispatches; this pins the image arm as the contended stage).
pub const DIT_STEP_ITERS: usize = 8;

/// Packed-fractional vs whole-GPU packing at equal hardware.
#[derive(Debug, Clone)]
pub struct FractionalComparison {
    pub fractional: FracReport,
    pub whole: FracReport,
}

impl FractionalComparison {
    /// Relative mean-JCT win of the fractional arm (positive =
    /// fractional wins).
    pub fn jct_margin(&self) -> f64 {
        (self.whole.mean_jct() - self.fractional.mean_jct()) / self.whole.mean_jct()
    }
}

/// The canonical fractional-sharing evaluation (the acceptance property
/// of the gpu_share subsystem): 48 requests of
/// [`datasets::branching_fanout`] at 4 req/s through the branching
/// encoder → thinker → {DiT | talker → vocoder} pipeline on SIX devices
/// in two layouts.
///
/// * **whole** — every stage owns whole devices: encoder, thinker,
///   talker, vocoder x1 and DiT x2.
/// * **fractional** — the encoder and vocoder (each using a sliver of a
///   device) are carved into two [`FRAC_SLOT_MILLI`]-milli slots
///   co-resident on one device; the freed device buys a THIRD DiT
///   replica.
///
/// The DiT arm is the only contended stage (at this operating point the
/// whole layout's two DiT replicas run at or above saturation), so the
/// comparison is a pure 3-vs-2 capacity race on the critical arm against
/// a ~3x slowdown of two near-idle stages — which is why the fractional
/// arm wins mean JCT on every seed, not just on average.  The fractional
/// layout is grounded on the real primitives each run: [`MilliLedger`]
/// packs both fractions into the one spare device and [`DeviceShare`]
/// admits both slots' hard memory partitions.  Shared by `omni-serve
/// bench --trace fractional` (the CI gate), `benches/sched_batching.rs`,
/// and `tests/scheduler.rs` so the harness cannot drift between them.
pub fn fractional_comparison(seed: u64) -> FractionalComparison {
    let wl = datasets::branching_fanout(seed, 48, 4.0, 20);

    // Ground the fractional layout: five whole slots (thinker, talker,
    // 3x DiT) leave one device whose spare milli the ledger packs both
    // fractions into, and the memory partition admits both slots.
    let mut ledger = MilliLedger::new(6);
    for _ in 0..5 {
        let d = ledger.pack(DEVICE_MILLI).expect("whole slot fits");
        ledger.commit(d, DEVICE_MILLI);
    }
    let enc_dev = ledger.pack(FRAC_SLOT_MILLI).expect("encoder fraction fits");
    ledger.commit(enc_dev, FRAC_SLOT_MILLI);
    let voc_dev = ledger.pack(FRAC_SLOT_MILLI).expect("vocoder fraction fits");
    ledger.commit(voc_dev, FRAC_SLOT_MILLI);
    assert_eq!(enc_dev, voc_dev, "both fractions pack into the same spare device");
    let pool = DevicePool::new(6, DEFAULT_DEVICE_BYTES);
    let share = DeviceShare::new(DeviceId(enc_dev));
    let quarter = DEFAULT_DEVICE_BYTES / 4;
    let enc_slot = share
        .carve(&pool, FracSlot { compute_milli: FRAC_SLOT_MILLI, mem_bytes: quarter }, "enc-frac")
        .expect("encoder slot admits");
    let voc_slot = share
        .carve(&pool, FracSlot { compute_milli: FRAC_SLOT_MILLI, mem_bytes: quarter }, "voc-frac")
        .expect("vocoder slot admits");
    share.free(&pool, &voc_slot);
    share.free(&pool, &enc_slot);

    // Stage order: 0 encoder, 1 thinker (fans out), 2 imagegen (exit),
    // 3 talker, 4 vocoder (exit).
    let reqs: Vec<FracRequest> = wl
        .requests
        .iter()
        .map(|r| {
            let input = r.total_input_tokens().max(1);
            let audio = r.max_audio_tokens.max(1);
            FracRequest {
                id: r.id,
                arrival_s: r.arrival_s,
                work: vec![
                    StageWork { prefill: 0, decode: (input / 8).max(1) },
                    StageWork { prefill: input, decode: r.max_text_tokens.max(1) },
                    StageWork { prefill: 0, decode: r.diffusion_steps.max(1) * DIT_STEP_ITERS },
                    StageWork { prefill: 0, decode: audio },
                    StageWork { prefill: 0, decode: (audio / 4).max(1) },
                ],
            }
        })
        .collect();
    let cost = SimCost::default();
    let stage = |name: &'static str, max_batch: usize, milli: Vec<u32>, next: Vec<usize>| {
        FracStage { name, max_batch, replica_milli: milli, next }
    };
    let frac_stages = vec![
        stage("encoder", 4, vec![FRAC_SLOT_MILLI], vec![1]),
        stage("thinker", 4, vec![DEVICE_MILLI], vec![2, 3]),
        stage("imagegen", 1, vec![DEVICE_MILLI; 3], vec![]),
        stage("talker", 4, vec![DEVICE_MILLI], vec![4]),
        stage("vocoder", 4, vec![FRAC_SLOT_MILLI], vec![]),
    ];
    let whole_stages = vec![
        stage("encoder", 4, vec![DEVICE_MILLI], vec![1]),
        stage("thinker", 4, vec![DEVICE_MILLI], vec![2, 3]),
        stage("imagegen", 1, vec![DEVICE_MILLI; 2], vec![]),
        stage("talker", 4, vec![DEVICE_MILLI], vec![4]),
        stage("vocoder", 4, vec![DEVICE_MILLI], vec![]),
    ];
    let mut fractional = simulate_fractional(&frac_stages, &cost, &reqs);
    fractional.label = "fractional".into();
    let mut whole = simulate_fractional(&whole_stages, &cost, &reqs);
    whole.label = "whole".into();
    FractionalComparison { fractional, whole }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::policy::{ContinuousBatchingPolicy, FifoPolicy};
    use crate::trace::datasets;

    fn run(policy: &mut dyn BatchPolicy, wl: &Workload) -> SimReport {
        simulate(policy, 4, &SimCost::default(), &from_workload(wl))
    }

    #[test]
    fn all_requests_complete_under_every_policy() {
        let wl = datasets::librispeech(7, 24, 0.0);
        for policy in [
            &mut FifoPolicy as &mut dyn BatchPolicy,
            &mut ContinuousBatchingPolicy { max_batch_tokens: 0 },
            &mut ContinuousBatchingPolicy { max_batch_tokens: 96 },
        ] {
            let rep = run(policy, &wl);
            assert_eq!(rep.jct.len(), wl.len(), "policy {}", rep.policy);
            assert!(rep.makespan_s > 0.0);
        }
    }

    #[test]
    fn continuous_beats_fifo_mean_jct_offline() {
        let wl = datasets::librispeech(1, 32, 0.0);
        let fifo = run(&mut FifoPolicy, &wl);
        let cont = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 0 }, &wl);
        assert!(
            cont.mean_jct() < fifo.mean_jct(),
            "continuous {:.3}s !< fifo {:.3}s",
            cont.mean_jct(),
            fifo.mean_jct()
        );
    }

    #[test]
    fn continuous_beats_fifo_mean_jct_online() {
        let wl = datasets::seedtts(3, 32, 4.0);
        let fifo = run(&mut FifoPolicy, &wl);
        let cont = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 0 }, &wl);
        assert!(
            cont.mean_jct() < fifo.mean_jct(),
            "continuous {:.3}s !< fifo {:.3}s",
            cont.mean_jct(),
            fifo.mean_jct()
        );
        // Continuous batching also keeps the batch fuller.
        assert!(cont.mean_batch > fifo.mean_batch);
    }

    #[test]
    fn token_budget_caps_occupancy() {
        let wl = datasets::librispeech(5, 16, 0.0);
        let open = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 0 }, &wl);
        let tight = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 64 }, &wl);
        assert!(tight.mean_batch <= open.mean_batch);
        assert_eq!(tight.jct.len(), wl.len(), "budget must not starve requests");
    }

    #[test]
    fn simulation_is_deterministic() {
        let wl = datasets::ucf101(9, 12, 2.0);
        let a = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 0 }, &wl);
        let b = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 0 }, &wl);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.iterations, b.iterations);
    }

    fn continuous_replicas(n: usize) -> Vec<Box<dyn BatchPolicy>> {
        (0..n)
            .map(|_| Box::new(ContinuousBatchingPolicy { max_batch_tokens: 0 }) as Box<dyn BatchPolicy>)
            .collect()
    }

    #[test]
    fn replicated_stage_completes_everything_under_every_routing() {
        let wl = datasets::seedtts(11, 24, 0.0);
        let reqs = from_workload(&wl);
        for routing in [SimRouting::RoundRobin, SimRouting::LeastWork, SimRouting::Affinity] {
            let mut ps = continuous_replicas(2);
            let rep = simulate_replicated(&mut ps, 4, &SimCost::default(), &reqs, routing);
            assert_eq!(rep.jct.len(), wl.len(), "routing {routing:?}");
            assert!(rep.makespan_s > 0.0);
        }
    }

    #[test]
    fn two_replicas_beat_one_on_mean_jct() {
        // The acceptance claim behind `benches/sched_batching.rs`: adding
        // a second engine replica to the hot stage cuts mean JCT on the
        // same trace.
        let wl = datasets::librispeech(13, 32, 0.0);
        let reqs = from_workload(&wl);
        let one = simulate(
            &mut ContinuousBatchingPolicy { max_batch_tokens: 0 },
            4,
            &SimCost::default(),
            &reqs,
        );
        for routing in [SimRouting::RoundRobin, SimRouting::LeastWork, SimRouting::Affinity] {
            let mut ps = continuous_replicas(2);
            let two = simulate_replicated(&mut ps, 4, &SimCost::default(), &reqs, routing);
            assert_eq!(two.jct.len(), one.jct.len());
            assert!(
                two.mean_jct() < one.mean_jct(),
                "{routing:?}: x2 {:.3}s !< x1 {:.3}s",
                two.mean_jct(),
                one.mean_jct()
            );
        }
    }

    #[test]
    fn single_replica_routed_run_matches_the_plain_simulation() {
        // replicas == 1 must be byte-for-byte the unrouted behaviour.
        let wl = datasets::seedtts(5, 16, 4.0);
        let reqs = from_workload(&wl);
        let plain = simulate(
            &mut ContinuousBatchingPolicy { max_batch_tokens: 0 },
            4,
            &SimCost::default(),
            &reqs,
        );
        let mut ps = continuous_replicas(1);
        let routed =
            simulate_replicated(&mut ps, 4, &SimCost::default(), &reqs, SimRouting::Affinity);
        assert_eq!(plain.policy, routed.policy);
        assert_eq!(plain.iterations, routed.iterations);
        assert_eq!(plain.makespan_s, routed.makespan_s);
        assert_eq!(plain.jct.len(), routed.jct.len());
        assert_eq!(plain.jct.mean(), routed.jct.mean());
    }

    #[test]
    fn replicated_simulation_is_deterministic() {
        let wl = datasets::ucf101(17, 18, 2.0);
        let reqs = from_workload(&wl);
        let mut a_ps = continuous_replicas(3);
        let mut b_ps = continuous_replicas(3);
        let a = simulate_replicated(&mut a_ps, 4, &SimCost::default(), &reqs, SimRouting::LeastWork);
        let b = simulate_replicated(&mut b_ps, 4, &SimCost::default(), &reqs, SimRouting::LeastWork);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.iterations, b.iterations);
    }

    // -----------------------------------------------------------------
    // Elastic model.
    // -----------------------------------------------------------------

    const TWO_STAGES: [ElasticStage; 2] = [
        ElasticStage { name: "thinker", max_batch: 4 },
        ElasticStage { name: "talker", max_batch: 4 },
    ];

    #[test]
    fn elastic_single_stage_static_matches_the_plain_simulation() {
        // One static replica of one stage must reproduce `simulate` with
        // slot-bound continuous batching exactly (same timing skeleton).
        let wl = datasets::librispeech(5, 24, 3.0);
        let plain_reqs = from_workload(&wl);
        let plain = simulate(
            &mut ContinuousBatchingPolicy { max_batch_tokens: 0 },
            4,
            &SimCost::default(),
            &plain_reqs,
        );
        let ereqs: Vec<ElasticRequest> = plain_reqs
            .iter()
            .map(|r| ElasticRequest {
                id: r.id,
                arrival_s: r.arrival_s,
                work: vec![StageWork { prefill: r.prefill_tokens, decode: r.decode_tokens }],
            })
            .collect();
        let elastic = simulate_elastic(
            &[ElasticStage { name: "ar", max_batch: 4 }],
            &SimCost::default(),
            &ereqs,
            &ElasticAllocation::Static(vec![1]),
        );
        assert_eq!(elastic.jct.len(), plain.jct.len());
        assert!((elastic.makespan_s - plain.makespan_s).abs() < 1e-9);
        assert!((elastic.mean_jct() - plain.mean_jct()).abs() < 1e-9);
    }

    #[test]
    fn elastic_completes_everything_static_and_autoscaled() {
        let wl = datasets::bursty_mixed(11, 24, 1.5);
        let reqs = two_stage_from_workload(&wl);
        for alloc in [
            ElasticAllocation::Static(vec![2, 2]),
            ElasticAllocation::Auto(bench_autoscaler(4)),
        ] {
            let rep = simulate_elastic(&TWO_STAGES, &SimCost::default(), &reqs, &alloc);
            assert_eq!(rep.jct.len(), wl.len(), "{}", rep.policy);
            assert!(rep.makespan_s > 0.0);
        }
    }

    #[test]
    fn autoscaler_stays_within_budget_and_scales_both_ways() {
        let wl = datasets::bursty_mixed(3, 32, 2.0);
        let reqs = two_stage_from_workload(&wl);
        let auto = bench_autoscaler(4);
        let rep = simulate_elastic(
            &TWO_STAGES,
            &SimCost::default(),
            &reqs,
            &ElasticAllocation::Auto(auto.clone()),
        );
        assert!(rep.max_slots <= auto.gpu_budget, "peak {} > budget", rep.max_slots);
        assert!(rep.scale_ups >= 1, "no scale-up on a bursty trace");
        assert!(rep.scale_downs >= 1, "no scale-down on a bursty trace");
        // Elasticity buys the JCT win while holding FEWER GPU-seconds
        // than the always-on static budget.
        assert!(rep.replica_seconds < auto.gpu_budget as f64 * rep.makespan_s);
        // The timeline never shows a stage below the floor.
        for (_, counts) in &rep.timeline {
            assert!(counts.iter().all(|&c| c >= auto.min_replicas));
        }
    }

    // -----------------------------------------------------------------
    // Prefill/decode disaggregation model.
    // -----------------------------------------------------------------

    /// The canonical disagg evaluation setup (also used by the bench,
    /// the CLI smoke, and tests/disagg.rs): 64 requests of the
    /// prefill-heavy trace at 56 req/s, GPU budget 4.
    fn disagg_case(seed: u64) -> DisaggComparison {
        simulate_disagg(&datasets::prefill_heavy(seed, 64, 56.0), 4)
    }

    #[test]
    fn disagg_completes_everything_in_every_configuration() {
        let c = disagg_case(2);
        for rep in [&c.fused, &c.fused_wide, &c.split_static, &c.split_auto] {
            assert_eq!(rep.jct.len(), 64, "{}", rep.policy);
            assert_eq!(rep.ttft.len(), 64, "{}", rep.policy);
            assert!(rep.makespan_s > 0.0);
        }
    }

    #[test]
    fn disagg_beats_fused_on_jct_and_ttft_at_equal_budget() {
        // The acceptance property: on the prefill-heavy mixed trace the
        // split pools beat the fused pool — at WHICHEVER batch cap suits
        // it better — on BOTH mean JCT and mean TTFT, at the same GPU
        // budget.  (Python-mirror validation: the static split wins with
        // ≥17% JCT / ≥4% TTFT margins across 32 seeds against the
        // best-of-caps fused baseline at this operating point.)
        for seed in [1, 2, 3] {
            let c = disagg_case(seed);
            assert!(
                c.split_static.mean_jct() < c.fused_best_jct(),
                "seed {seed}: split {:.4}s !< best fused {:.4}s mean JCT",
                c.split_static.mean_jct(),
                c.fused_best_jct()
            );
            assert!(
                c.split_static.mean_ttft() < c.fused_best_ttft(),
                "seed {seed}: split {:.4}s !< best fused {:.4}s mean TTFT",
                c.split_static.mean_ttft(),
                c.fused_best_ttft()
            );
            // The autoscaled pools keep the JCT win within the budget.
            assert!(
                c.split_auto.mean_jct() < c.fused_best_jct(),
                "seed {seed}: autoscaled"
            );
            assert!(c.split_auto.max_slots <= 4, "seed {seed}: budget violated");
        }
    }

    #[test]
    fn disagg_autoscaler_scales_each_pool_independently() {
        let c = disagg_case(1);
        let auto = &c.split_auto;
        assert_eq!(auto.stage_scale_ups.len(), 2);
        assert!(
            auto.stage_scale_ups[0] >= 1,
            "no scale event in the prefill pool: {:?}",
            auto.stage_scale_ups
        );
        assert!(
            auto.stage_scale_ups[1] >= 1,
            "no scale event in the decode pool: {:?}",
            auto.stage_scale_ups
        );
        // Aggregate counters stay consistent with the per-stage view.
        assert_eq!(auto.scale_ups, auto.stage_scale_ups.iter().sum::<usize>());
        assert_eq!(auto.scale_downs, auto.stage_scale_downs.iter().sum::<usize>());
    }

    #[test]
    fn disagg_simulation_is_deterministic() {
        let a = disagg_case(3);
        let b = disagg_case(3);
        assert_eq!(a.fused.makespan_s, b.fused.makespan_s);
        assert_eq!(a.split_static.jct.mean(), b.split_static.jct.mean());
        assert_eq!(a.split_auto.scale_ups, b.split_auto.scale_ups);
        assert_eq!(a.split_auto.ttft.mean(), b.split_auto.ttft.mean());
    }

    #[test]
    fn per_phase_dispatch_only_charges_mixed_iterations() {
        // A single-phase stage costs the same either way; the flag only
        // penalizes iterations mixing prefill and decode lanes.
        let reqs: Vec<ElasticRequest> = (0..6)
            .map(|i| ElasticRequest {
                id: i,
                arrival_s: 0.0,
                work: vec![StageWork { prefill: 0, decode: 20 }],
            })
            .collect();
        let single = SimCost::default();
        let per_phase = SimCost { per_phase_dispatch: true, ..SimCost::default() };
        let stages = [ElasticStage { name: "d", max_batch: 4 }];
        let a = simulate_elastic(&stages, &single, &reqs, &ElasticAllocation::Static(vec![2]));
        let b = simulate_elastic(&stages, &per_phase, &reqs, &ElasticAllocation::Static(vec![2]));
        assert_eq!(a.makespan_s, b.makespan_s, "pure-decode pools are unaffected");
        // A fused pool whose iterations mix phases IS slower under
        // per-phase dispatch: staggered arrivals put a prefilling lane
        // next to decoding lanes (simultaneous identical lanes would
        // stay in lockstep and never mix).
        let mixed: Vec<ElasticRequest> = (0..4)
            .map(|i| ElasticRequest {
                id: i,
                arrival_s: i as f64 * 0.03,
                work: vec![StageWork { prefill: 64, decode: 40 }],
            })
            .collect();
        let a = simulate_elastic(&stages, &single, &mixed, &ElasticAllocation::Static(vec![1]));
        let b = simulate_elastic(&stages, &per_phase, &mixed, &ElasticAllocation::Static(vec![1]));
        assert!(b.makespan_s > a.makespan_s, "mixed iterations must pay both dispatches");
    }

    #[test]
    fn elastic_simulation_is_deterministic() {
        let wl = datasets::bursty_mixed(9, 28, 2.0);
        let reqs = two_stage_from_workload(&wl);
        let alloc = ElasticAllocation::Auto(bench_autoscaler(4));
        let a = simulate_elastic(&TWO_STAGES, &SimCost::default(), &reqs, &alloc);
        let b = simulate_elastic(&TWO_STAGES, &SimCost::default(), &reqs, &alloc);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.scale_ups, b.scale_ups);
        assert_eq!(a.scale_downs, b.scale_downs);
        assert_eq!(a.jct.mean(), b.jct.mean());
    }

    // -----------------------------------------------------------------
    // SLO-aware overload model.
    // -----------------------------------------------------------------

    #[test]
    fn overload_accounts_every_offered_request_exactly_once() {
        for mult in [2.0, 5.0] {
            let c = overload_comparison(7, 4, mult);
            for rep in [&c.fifo, &c.admission] {
                assert_eq!(
                    rep.in_slo + rep.missed + rep.expired + rep.rejected + rep.shed,
                    rep.offered,
                    "{} at {mult}x leaks requests",
                    rep.policy
                );
                assert_eq!(rep.jct.len(), rep.in_slo, "{}", rep.policy);
            }
            // The FIFO arm neither rejects nor sheds — deadlines are its
            // only loss mechanism.
            assert_eq!(c.fifo.rejected, 0);
            assert_eq!(c.fifo.shed, 0);
        }
    }

    #[test]
    fn admission_beats_fifo_goodput_at_every_overload_multiple() {
        for mult in [2.0, 3.0, 5.0] {
            let c = overload_comparison(1, 4, mult);
            assert!(
                c.margin() > 0.0,
                "{mult}x: admission {:.3} !> fifo {:.3} goodput",
                c.admission.goodput(),
                c.fifo.goodput()
            );
        }
    }

    #[test]
    fn admission_burns_less_lane_time_than_fifo() {
        // The mechanism behind the goodput win: FIFO starts doomed work
        // and cancels it mid-service; admission refuses to start it.
        let c = overload_comparison(3, 4, 3.0);
        assert!(
            c.admission.burned_s < c.fifo.burned_s,
            "admission burned {:.3}s !< fifo {:.3}s",
            c.admission.burned_s,
            c.fifo.burned_s
        );
    }

    #[test]
    fn overload_model_is_deterministic() {
        let a = overload_comparison(5, 4, 3.0);
        let b = overload_comparison(5, 4, 3.0);
        assert_eq!(a.fifo.goodput(), b.fifo.goodput());
        assert_eq!(a.admission.in_slo, b.admission.in_slo);
        assert_eq!(a.admission.rejected, b.admission.rejected);
        assert_eq!(a.admission.jct.mean(), b.admission.jct.mean());
    }

    // -----------------------------------------------------------------
    // Prefix-cache model.
    // -----------------------------------------------------------------

    #[test]
    fn prefix_cache_completes_everything_in_both_arms() {
        let c = prefix_cache_comparison(2, 4);
        for rep in [&c.cached, &c.cold] {
            assert_eq!(rep.jct.len(), 64, "{}", rep.policy);
            assert_eq!(rep.ttft.len(), 64, "{}", rep.policy);
            assert!(rep.makespan_s > 0.0);
        }
        // The cold arm never attaches anything, by construction.
        assert_eq!(c.cold.hits, 0);
        assert_eq!(c.cold.tokens_skipped, 0);
    }

    #[test]
    fn prefix_cache_attaches_blocks_on_the_shared_prefix_trace() {
        let c = prefix_cache_comparison(1, 4);
        assert!(c.cached.hits >= 8, "only {} attaches on a hot trace", c.cached.hits);
        // Every attach is block-aligned and at least one block long.
        assert!(c.cached.tokens_skipped >= c.cached.hits * 16);
        assert_eq!(c.cached.tokens_skipped % 16, 0);
    }

    #[test]
    fn prefix_cache_beats_cold_on_ttft_and_jct() {
        for seed in [1, 2, 3] {
            let c = prefix_cache_comparison(seed, 4);
            assert!(
                c.cached.mean_ttft() < c.cold.mean_ttft(),
                "seed {seed}: cached {:.4}s !< cold {:.4}s mean TTFT",
                c.cached.mean_ttft(),
                c.cold.mean_ttft()
            );
            assert!(
                c.cached.mean_jct() < c.cold.mean_jct(),
                "seed {seed}: cached {:.4}s !< cold {:.4}s mean JCT",
                c.cached.mean_jct(),
                c.cold.mean_jct()
            );
        }
    }

    #[test]
    fn prefix_cache_is_inert_without_shared_prefixes() {
        // Unique prompts never attach: the cached arm must be
        // byte-for-byte the cold arm (the cache costs nothing when it
        // cannot help).
        let wl = datasets::librispeech(5, 24, 8.0);
        let reqs = prefix_from_workload(&wl);
        let cost = SimCost::default();
        let cached = simulate_prefix_cache(&reqs, 4, &cost, true);
        let cold = simulate_prefix_cache(&reqs, 4, &cost, false);
        assert_eq!(cached.hits, 0, "librispeech prompts are unique");
        assert_eq!(cached.makespan_s, cold.makespan_s);
        assert_eq!(cached.jct.mean(), cold.jct.mean());
        assert_eq!(cached.ttft.mean(), cold.ttft.mean());
    }

    #[test]
    fn prefix_cache_model_is_deterministic() {
        let a = prefix_cache_comparison(7, 4);
        let b = prefix_cache_comparison(7, 4);
        assert_eq!(a.cached.makespan_s, b.cached.makespan_s);
        assert_eq!(a.cached.tokens_skipped, b.cached.tokens_skipped);
        assert_eq!(a.cached.jct.mean(), b.cached.jct.mean());
        assert_eq!(a.cold.ttft.mean(), b.cold.ttft.mean());
    }

    #[test]
    fn tight_horizon_sheds_queued_work_and_still_accounts_for_it() {
        // A lenient slack over-admits; a tight horizon then sheds from
        // the queue.  Shedding only ever removes queue entries (lanes
        // are structurally untouchable in `run_overload`), and every
        // shed request still lands in a terminal bucket.
        let wl = datasets::overload_storm(11, 96, 40.0);
        let cfg = AdmissionConfig {
            slack: 0.25,
            shed_horizon_s: 0.4,
            ..AdmissionConfig::default()
        };
        let c = simulate_admission(&wl, 2, &cfg);
        let a = &c.admission;
        assert!(a.shed > 0, "tight horizon on an overload storm must shed");
        assert_eq!(a.in_slo + a.missed + a.expired + a.rejected + a.shed, a.offered);
    }

    // ----- cross-node placement model --------------------------------

    #[test]
    fn cross_node_comparison_completes_every_request_in_both_arms() {
        let c = cross_node_comparison(1);
        assert_eq!(c.transfer_aware.jct.len(), 48);
        assert_eq!(c.round_robin.jct.len(), 48);
        assert!(c.transfer_aware.makespan_s > 0.0);
    }

    #[test]
    fn transfer_aware_placement_beats_round_robin_on_jct() {
        // The full 32-seed sweep is the CI gate (`bench --trace
        // cross-node` + tests/scheduler.rs); spot-check a few here.
        for seed in [1, 2, 3] {
            let c = cross_node_comparison(seed);
            assert!(
                c.transfer_aware.mean_jct() < c.round_robin.mean_jct(),
                "seed {seed}: transfer-aware {:.2} ms !< round-robin {:.2} ms",
                c.transfer_aware.mean_jct() * 1e3,
                c.round_robin.mean_jct() * 1e3,
            );
            assert!(
                c.jct_margin() > 0.03,
                "seed {seed}: margin {:.2}% below the 3% floor",
                c.jct_margin() * 100.0,
            );
        }
    }

    #[test]
    fn transfer_aware_placement_crosses_only_the_light_edge() {
        // Both replica pairs of the KV edge are co-located under the
        // transfer-aware plan, so only the 8 KiB vocoder hop pays the
        // link: one cross-transfer per request vs two under round-robin
        // (which misaligns every hop).
        let c = cross_node_comparison(1);
        assert_eq!(c.transfer_aware.cross_transfers, 48);
        assert_eq!(c.round_robin.cross_transfers, 96);
        assert!(c.transfer_aware.transfer_s < c.round_robin.transfer_s);
        for r in 0..2 {
            assert_eq!(
                c.aware_plan.node_of("prefill", r),
                c.aware_plan.node_of("decode", r),
                "aware plan must co-locate the KV edge's replica pair {r}",
            );
        }
    }

    #[test]
    fn cross_node_model_is_deterministic() {
        let a = cross_node_comparison(9);
        let b = cross_node_comparison(9);
        assert_eq!(a.transfer_aware.makespan_s, b.transfer_aware.makespan_s);
        assert_eq!(a.transfer_aware.mean_jct(), b.transfer_aware.mean_jct());
        assert_eq!(a.round_robin.cross_transfers, b.round_robin.cross_transfers);
        assert_eq!(a.transfer_aware.transfer_s, b.transfer_aware.transfer_s);
    }
}
