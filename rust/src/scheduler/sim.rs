//! Deterministic discrete-time model of an AR stage, for evaluating
//! [`BatchPolicy`] implementations without compiled artifacts.
//!
//! The real AR engine is a synchronous state machine: each iteration runs
//! one bucketed executable over the active batch (a prefill chunk per
//! prefilling sequence, one token per decoding sequence) and sequences
//! join/evict at those boundaries.  This module reproduces exactly that
//! timing skeleton with a two-parameter cost model — a fixed per-iteration
//! dispatch cost plus a marginal per-token cost — so policy-level effects
//! (convoy delays under static batching, slot refill under continuous
//! batching, token-budget admission) appear with the right shape while
//! runs stay reproducible to the bit.
//!
//! `benches/sched_batching.rs` drives this model over the bundled trace
//! generators ([`crate::trace::datasets`]); the integration tests pin the
//! headline property (continuous batching beats FIFO mean JCT on the AR
//! traces) so it cannot silently regress.

use super::policy::{BatchPolicy, EngineView, PendingJob};
use crate::trace::Workload;
use crate::util::stats::Samples;

/// One request as the simulated stage sees it.
#[derive(Debug, Clone)]
pub struct SimRequest {
    pub id: u64,
    pub arrival_s: f64,
    /// Prompt tokens to prefill (text + multimodal frames).
    pub prefill_tokens: usize,
    /// Tokens to generate after prefill.
    pub decode_tokens: usize,
}

/// Map a trace workload onto simulated AR requests (prompt = text +
/// encoder frames, generation = the text-stage budget).
pub fn from_workload(wl: &Workload) -> Vec<SimRequest> {
    wl.requests
        .iter()
        .map(|r| SimRequest {
            id: r.id,
            arrival_s: r.arrival_s,
            prefill_tokens: r.total_input_tokens().max(1),
            decode_tokens: r.max_text_tokens.max(1),
        })
        .collect()
}

/// Iteration cost model.  Defaults approximate the CPU-PJRT testbed's
/// decode-step decomposition (dispatch-dominated, weak per-token slope —
/// see `benches/perf_micro.rs`).
#[derive(Debug, Clone)]
pub struct SimCost {
    /// Fixed cost per engine iteration (dispatch, KV marshaling).
    pub base_s: f64,
    /// Marginal cost per token processed in an iteration.
    pub token_s: f64,
    /// Prompt tokens consumed per prefilling sequence per iteration
    /// (chunked prefill).
    pub prefill_chunk: usize,
}

impl Default for SimCost {
    fn default() -> Self {
        Self {
            base_s: 4e-3,
            token_s: 0.25e-3,
            prefill_chunk: crate::engine::ar::PREFILL_CHUNK,
        }
    }
}

/// Aggregate results of one simulated run.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub policy: String,
    /// Per-request job completion times (arrival → last token).
    pub jct: Samples,
    pub iterations: u64,
    pub makespan_s: f64,
    /// Mean batch occupancy over iterations (batching effectiveness).
    pub mean_batch: f64,
}

impl SimReport {
    pub fn mean_jct(&self) -> f64 {
        self.jct.mean()
    }
}

struct Active {
    arrival_s: f64,
    prefill_left: usize,
    decode_left: usize,
    /// Constant token commitment (prompt + generation budget), matching
    /// `ArEngine::committed_tokens` — the real engine's admission signal
    /// does not decay as tokens are produced, only on eviction.
    commitment: usize,
}

/// Serve `reqs` through a simulated AR stage under `policy`.
pub fn simulate(
    policy: &mut dyn BatchPolicy,
    max_batch: usize,
    cost: &SimCost,
    reqs: &[SimRequest],
) -> SimReport {
    let mut arrivals: Vec<&SimRequest> = reqs.iter().collect();
    arrivals.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    let mut next_arrival = 0usize;
    let mut queue: Vec<&SimRequest> = Vec::new();
    let mut active: Vec<Active> = Vec::new();

    let mut t = 0.0f64;
    let mut jct = Samples::new();
    let mut iterations = 0u64;
    let mut occupancy = 0u64;

    loop {
        // Arrivals up to the current time.
        while next_arrival < arrivals.len() && arrivals[next_arrival].arrival_s <= t {
            queue.push(arrivals[next_arrival]);
            next_arrival += 1;
        }
        if active.is_empty() && queue.is_empty() {
            match arrivals.get(next_arrival) {
                // Idle until the next request arrives.
                Some(r) => {
                    t = r.arrival_s;
                    continue;
                }
                None => break,
            }
        }

        // Admission at the token boundary.
        if !queue.is_empty() {
            let view = EngineView {
                running: active.len(),
                max_batch,
                committed_tokens: active.iter().map(|a| a.commitment).sum(),
                lane_steps: vec![],
            };
            let jobs: Vec<PendingJob> = queue
                .iter()
                .map(|r| PendingJob {
                    req_id: r.id,
                    cost_tokens: r.prefill_tokens + r.decode_tokens,
                })
                .collect();
            let mut n = policy.admit(&jobs, &view).min(queue.len());
            if active.is_empty() && n == 0 {
                // Safety valve: a policy must not stall an empty engine.
                debug_assert!(false, "policy {} stalled an empty engine", policy.name());
                n = 1;
            }
            for r in queue.drain(..n) {
                active.push(Active {
                    arrival_s: r.arrival_s,
                    prefill_left: r.prefill_tokens,
                    decode_left: r.decode_tokens,
                    commitment: r.prefill_tokens + r.decode_tokens,
                });
            }
        }
        if active.is_empty() {
            // Queue non-empty but policy is waiting (cannot happen with an
            // empty engine thanks to the valve above).
            continue;
        }

        // One engine iteration.
        let mut tokens = 0usize;
        for a in &active {
            tokens += if a.prefill_left > 0 { a.prefill_left.min(cost.prefill_chunk) } else { 1 };
        }
        t += cost.base_s + cost.token_s * tokens as f64;
        iterations += 1;
        occupancy += active.len() as u64;

        // Advance sequences; the iteration that finishes a prompt also
        // samples the first token (matching the real prefill path).
        for a in &mut active {
            if a.prefill_left > 0 {
                let consumed = a.prefill_left.min(cost.prefill_chunk);
                a.prefill_left -= consumed;
                if a.prefill_left == 0 {
                    a.decode_left = a.decode_left.saturating_sub(1);
                }
            } else {
                a.decode_left = a.decode_left.saturating_sub(1);
            }
        }
        // Evict at the token boundary.
        active.retain(|a| {
            let done = a.prefill_left == 0 && a.decode_left == 0;
            if done {
                jct.push(t - a.arrival_s);
            }
            !done
        });
    }

    SimReport {
        policy: policy.name().to_string(),
        jct,
        iterations,
        makespan_s: t,
        mean_batch: if iterations > 0 { occupancy as f64 / iterations as f64 } else { 0.0 },
    }
}

/// How the routed edge layer assigns requests to a replicated stage's
/// engines in the sim (mirrors [`crate::config::RoutingKind`] at the
/// request granularity — in the real pipeline per-request stickiness is
/// what the affinity policy guarantees, and round-robin/least-depth
/// route single-item requests identically).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimRouting {
    /// Arrival-order rotation across replicas.
    RoundRobin,
    /// Greedy work balance: each request goes to the replica with the
    /// least total token-work assigned so far (the sim's stand-in for
    /// live queue-depth feedback).
    LeastWork,
    /// `req_id % replicas` — the router's affinity hash.
    Affinity,
}

impl SimRouting {
    pub fn name(self) -> &'static str {
        match self {
            SimRouting::RoundRobin => "round-robin",
            SimRouting::LeastWork => "least-work",
            SimRouting::Affinity => "affinity",
        }
    }
}

/// Serve `reqs` through a stage replicated across `policies.len()`
/// engines (paper §3.3 flexible GPU allocation): the routing policy
/// partitions requests across replicas at arrival, each replica runs the
/// standard single-engine simulation on its share, and the reports merge.
/// With one replica this is exactly [`simulate`].
pub fn simulate_replicated(
    policies: &mut [Box<dyn BatchPolicy>],
    max_batch: usize,
    cost: &SimCost,
    reqs: &[SimRequest],
    routing: SimRouting,
) -> SimReport {
    let n = policies.len();
    assert!(n >= 1, "need at least one replica");
    // Route at arrival, deterministically.
    let mut order: Vec<&SimRequest> = reqs.iter().collect();
    order.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s).then(a.id.cmp(&b.id)));
    let mut parts: Vec<Vec<SimRequest>> = (0..n).map(|_| vec![]).collect();
    let mut assigned_work = vec![0usize; n];
    for (k, r) in order.iter().enumerate() {
        let i = match routing {
            SimRouting::RoundRobin => k % n,
            SimRouting::Affinity => (r.id % n as u64) as usize,
            SimRouting::LeastWork => (0..n)
                .min_by_key(|&i| (assigned_work[i], i))
                .expect("n >= 1"),
        };
        assigned_work[i] += r.prefill_tokens + r.decode_tokens;
        parts[i].push((*r).clone());
    }
    // Each replica is an independent engine over its share.
    let mut jct = Samples::new();
    let mut iterations = 0u64;
    let mut makespan = 0.0f64;
    let mut occupancy = 0.0f64;
    let mut base_policy = String::new();
    for (policy, part) in policies.iter_mut().zip(&parts) {
        let rep = simulate(policy.as_mut(), max_batch, cost, part);
        jct.extend(&rep.jct);
        occupancy += rep.mean_batch * rep.iterations as f64;
        iterations += rep.iterations;
        makespan = makespan.max(rep.makespan_s);
        base_policy = rep.policy;
    }
    SimReport {
        policy: if n == 1 {
            base_policy
        } else {
            format!("{base_policy} x{n} ({})", routing.name())
        },
        jct,
        iterations,
        makespan_s: makespan,
        mean_batch: if iterations > 0 { occupancy / iterations as f64 } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::policy::{ContinuousBatchingPolicy, FifoPolicy};
    use crate::trace::datasets;

    fn run(policy: &mut dyn BatchPolicy, wl: &Workload) -> SimReport {
        simulate(policy, 4, &SimCost::default(), &from_workload(wl))
    }

    #[test]
    fn all_requests_complete_under_every_policy() {
        let wl = datasets::librispeech(7, 24, 0.0);
        for policy in [
            &mut FifoPolicy as &mut dyn BatchPolicy,
            &mut ContinuousBatchingPolicy { max_batch_tokens: 0 },
            &mut ContinuousBatchingPolicy { max_batch_tokens: 96 },
        ] {
            let rep = run(policy, &wl);
            assert_eq!(rep.jct.len(), wl.len(), "policy {}", rep.policy);
            assert!(rep.makespan_s > 0.0);
        }
    }

    #[test]
    fn continuous_beats_fifo_mean_jct_offline() {
        let wl = datasets::librispeech(1, 32, 0.0);
        let fifo = run(&mut FifoPolicy, &wl);
        let cont = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 0 }, &wl);
        assert!(
            cont.mean_jct() < fifo.mean_jct(),
            "continuous {:.3}s !< fifo {:.3}s",
            cont.mean_jct(),
            fifo.mean_jct()
        );
    }

    #[test]
    fn continuous_beats_fifo_mean_jct_online() {
        let wl = datasets::seedtts(3, 32, 4.0);
        let fifo = run(&mut FifoPolicy, &wl);
        let cont = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 0 }, &wl);
        assert!(
            cont.mean_jct() < fifo.mean_jct(),
            "continuous {:.3}s !< fifo {:.3}s",
            cont.mean_jct(),
            fifo.mean_jct()
        );
        // Continuous batching also keeps the batch fuller.
        assert!(cont.mean_batch > fifo.mean_batch);
    }

    #[test]
    fn token_budget_caps_occupancy() {
        let wl = datasets::librispeech(5, 16, 0.0);
        let open = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 0 }, &wl);
        let tight = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 64 }, &wl);
        assert!(tight.mean_batch <= open.mean_batch);
        assert_eq!(tight.jct.len(), wl.len(), "budget must not starve requests");
    }

    #[test]
    fn simulation_is_deterministic() {
        let wl = datasets::ucf101(9, 12, 2.0);
        let a = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 0 }, &wl);
        let b = run(&mut ContinuousBatchingPolicy { max_batch_tokens: 0 }, &wl);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.iterations, b.iterations);
    }

    fn continuous_replicas(n: usize) -> Vec<Box<dyn BatchPolicy>> {
        (0..n)
            .map(|_| Box::new(ContinuousBatchingPolicy { max_batch_tokens: 0 }) as Box<dyn BatchPolicy>)
            .collect()
    }

    #[test]
    fn replicated_stage_completes_everything_under_every_routing() {
        let wl = datasets::seedtts(11, 24, 0.0);
        let reqs = from_workload(&wl);
        for routing in [SimRouting::RoundRobin, SimRouting::LeastWork, SimRouting::Affinity] {
            let mut ps = continuous_replicas(2);
            let rep = simulate_replicated(&mut ps, 4, &SimCost::default(), &reqs, routing);
            assert_eq!(rep.jct.len(), wl.len(), "routing {routing:?}");
            assert!(rep.makespan_s > 0.0);
        }
    }

    #[test]
    fn two_replicas_beat_one_on_mean_jct() {
        // The acceptance claim behind `benches/sched_batching.rs`: adding
        // a second engine replica to the hot stage cuts mean JCT on the
        // same trace.
        let wl = datasets::librispeech(13, 32, 0.0);
        let reqs = from_workload(&wl);
        let one = simulate(
            &mut ContinuousBatchingPolicy { max_batch_tokens: 0 },
            4,
            &SimCost::default(),
            &reqs,
        );
        for routing in [SimRouting::RoundRobin, SimRouting::LeastWork, SimRouting::Affinity] {
            let mut ps = continuous_replicas(2);
            let two = simulate_replicated(&mut ps, 4, &SimCost::default(), &reqs, routing);
            assert_eq!(two.jct.len(), one.jct.len());
            assert!(
                two.mean_jct() < one.mean_jct(),
                "{routing:?}: x2 {:.3}s !< x1 {:.3}s",
                two.mean_jct(),
                one.mean_jct()
            );
        }
    }

    #[test]
    fn single_replica_routed_run_matches_the_plain_simulation() {
        // replicas == 1 must be byte-for-byte the unrouted behaviour.
        let wl = datasets::seedtts(5, 16, 4.0);
        let reqs = from_workload(&wl);
        let plain = simulate(
            &mut ContinuousBatchingPolicy { max_batch_tokens: 0 },
            4,
            &SimCost::default(),
            &reqs,
        );
        let mut ps = continuous_replicas(1);
        let routed =
            simulate_replicated(&mut ps, 4, &SimCost::default(), &reqs, SimRouting::Affinity);
        assert_eq!(plain.policy, routed.policy);
        assert_eq!(plain.iterations, routed.iterations);
        assert_eq!(plain.makespan_s, routed.makespan_s);
        assert_eq!(plain.jct.len(), routed.jct.len());
        assert_eq!(plain.jct.mean(), routed.jct.mean());
    }

    #[test]
    fn replicated_simulation_is_deterministic() {
        let wl = datasets::ucf101(17, 18, 2.0);
        let reqs = from_workload(&wl);
        let mut a_ps = continuous_replicas(3);
        let mut b_ps = continuous_replicas(3);
        let a = simulate_replicated(&mut a_ps, 4, &SimCost::default(), &reqs, SimRouting::LeastWork);
        let b = simulate_replicated(&mut b_ps, 4, &SimCost::default(), &reqs, SimRouting::LeastWork);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.iterations, b.iterations);
    }
}
