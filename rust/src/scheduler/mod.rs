//! Per-stage batching scheduler (paper §3.3: per-stage request batching +
//! flexible GPU allocation).
//!
//! The orchestrator runs every stage on its own thread with its own engine
//! ([`crate::orchestrator`]); this module is the layer between a stage's
//! *inputs* (frontend requests and upstream items arriving through
//! connectors) and its *engine*:
//!
//! ```text
//!   connectors ──► transfers ──► StageScheduler ──► engine.step()
//!                   (EngineCmd)   │ pending queue │
//!                                 │ BatchPolicy   │──► metrics::Recorder
//!                                 └───────────────┘    (queue depth,
//!                                                       occupancy,
//!                                                       admission waits)
//! ```
//!
//! Structure:
//! * [`policy`] — the [`BatchPolicy`] trait and the three built-in
//!   policies: continuous batching (AR), step-level batching (diffusion),
//!   FIFO (encoder/vocoder, and the static-batching baseline).
//! * [`allocator`] — [`StageAllocator`]: validates per-stage
//!   `devices`/`max_batch`/`sched` config into an [`AllocationPlan`]
//!   before any thread spawns.
//! * [`sim`] — a deterministic discrete-time model of an AR stage used to
//!   evaluate policies without compiled artifacts (drives
//!   `benches/sched_batching.rs` and the policy tests).
//! * [`StageScheduler`] — the per-stage admission queue each stage thread
//!   pulls batches from, in place of draining its connector straight into
//!   the engine.
//!
//! Scheduling is work-conserving and order-preserving: policies decide
//! *when* the front of the queue enters the engine, never reorder it.
//! Every submission — including each streaming chunk of a request — is
//! policy-gated uniformly, so competing requests are never starved by
//! another request's follow-up chunks and step-level cohorts actually
//! form; chunks are independent engine jobs, so gating them affects
//! latency only, never liveness.  Conditioning rows (`Upstream`) are the
//! one bypass: they buffer behind a still-queued head submission and
//! otherwise flow straight to the engine.  When the `queue_depth` cap is
//! reached the stage stops *pulling* from its connectors (bounding its
//! own queue — connector channels stay unbounded and producers never
//! block), which can delay rows still in the channel; that degrades
//! conditioning freshness but never liveness — engines do not block on
//! upstream rows (AR preprocessing uses whatever has arrived), so
//! in-flight work always completes and drains the queue.

pub mod allocator;
pub mod policy;
pub mod sim;

use std::collections::VecDeque;

use crate::stage_graph::transfers::EngineCmd;
use crate::util::stats::Samples;

pub use allocator::{AllocationPlan, StageAllocator, StageAssignment};
pub use policy::{
    BatchPolicy, ContinuousBatchingPolicy, EngineView, FifoPolicy, PendingJob, StepBatchingPolicy,
};

/// Default admission priority (the rank of
/// [`crate::serving::Priority::Normal`]).  Raw `u8` here so the
/// scheduler layer stays independent of the serving API types.
pub const PRIORITY_NORMAL: u8 = 1;

/// Aggregate scheduler counters for one stage (reported in
/// [`crate::orchestrator::StageSummary`]).
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    /// Resolved policy name ("continuous" / "step-level" / "fifo").
    pub policy: String,
    /// Submissions admitted into the engine through the queue (one per
    /// request for AR stages, one per streaming chunk for chunked
    /// stages).
    pub admitted: u64,
    /// Conditioning-row commands that bypassed the queue.
    pub passthrough: u64,
    /// Queued submissions dropped by [`StageScheduler::cancel`].
    pub cancelled: u64,
    /// High-water mark of the pending queue.
    pub max_queue_depth: usize,
    /// Seconds each admitted submission spent in the pending queue.
    pub queue_wait: Samples,
}

/// One queued submission plus everything that must follow it into the
/// engine (buffered conditioning rows).
struct Pending {
    job: PendingJob,
    cmd: EngineCmd,
    /// Admission priority class (higher enqueues ahead; weighted fair
    /// order within a class).
    prio: u8,
    /// Weighted-fair-queueing virtual finish tag (see
    /// [`StageScheduler::enqueue_wfq`]): within one priority class the
    /// queue is ordered by ascending tag, which degenerates to FIFO when
    /// every submission comes from one tenant.
    vft: f64,
    /// Upstream conditioning commands that arrived while this submission
    /// was still queued; replayed right after it is admitted (the engine
    /// drops rows for unknown request ids, so they must not run early).
    upstream: Vec<EngineCmd>,
    enqueued_at: f64,
}

/// The per-stage admission queue.  The stage thread feeds it every command
/// its transfers produce and asks [`StageScheduler::ready`] between engine
/// iterations which submissions the policy admits.
pub struct StageScheduler {
    policy: Box<dyn BatchPolicy>,
    /// Queue-depth cap (0 = unbounded): when full, [`Self::has_room`]
    /// turns false and the stage thread leaves items in the connector
    /// channel.
    queue_depth: usize,
    pending: VecDeque<Pending>,
    /// Per-tenant WFQ weights, indexed by interned tenant id (see
    /// [`crate::serving::admission`]); out-of-range tenants weigh 1.0.
    tenant_weights: Vec<f64>,
    /// Self-clocked fair-queueing virtual time: the finish tag of the
    /// last submission admitted into the engine.
    virtual_clock: f64,
    /// Last assigned finish tag per tenant id.
    tenant_finish: std::collections::HashMap<u32, f64>,
    pub stats: SchedStats,
}

impl StageScheduler {
    pub fn new(policy: Box<dyn BatchPolicy>, queue_depth: usize) -> Self {
        let stats = SchedStats { policy: policy.name().to_string(), ..Default::default() };
        Self {
            policy,
            queue_depth,
            pending: VecDeque::new(),
            tenant_weights: Vec::new(),
            virtual_clock: 0.0,
            tenant_finish: std::collections::HashMap::new(),
            stats,
        }
    }

    /// Install the per-tenant WFQ weights (index = interned tenant id;
    /// tenants beyond the vector weigh 1.0).  Typically called once at
    /// stage spawn from the session's admission config.
    pub fn set_tenant_weights(&mut self, weights: Vec<f64>) {
        self.tenant_weights = weights;
    }

    fn tenant_weight(&self, tenant: u32) -> f64 {
        self.tenant_weights
            .get(tenant as usize)
            .copied()
            .filter(|w| w.is_finite() && *w > 0.0)
            .unwrap_or(1.0)
    }

    /// Pending submissions (the stage's queue depth).
    pub fn queue_len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Whether the stage thread should keep pulling from its connectors.
    pub fn has_room(&self) -> bool {
        self.queue_depth == 0 || self.pending.len() < self.queue_depth
    }

    /// Offer a command at normal priority (see [`Self::enqueue_prio`]).
    pub fn enqueue(&mut self, cmd: EngineCmd, now: f64) -> Vec<EngineCmd> {
        self.enqueue_prio(cmd, now, PRIORITY_NORMAL)
    }

    /// Offer a command on behalf of the anonymous tenant (see
    /// [`Self::enqueue_wfq`]).  With a single tenant the fair-queueing
    /// tags are monotonic in arrival order, so this is exactly the
    /// pre-WFQ behaviour: FIFO within each priority class.
    pub fn enqueue_prio(&mut self, cmd: EngineCmd, now: f64, prio: u8) -> Vec<EngineCmd> {
        self.enqueue_wfq(cmd, now, prio, 0)
    }

    /// Offer a command.  Submissions (including every streaming chunk)
    /// are queued for admission control; conditioning rows return
    /// immediately when their target is not queued here (the engine
    /// either has the sequence or safely ignores unknown ids).
    ///
    /// `prio` orders the pending queue at insertion time: a submission
    /// enqueues behind everything of its class or higher and ahead of
    /// strictly lower classes (request-lifecycle priorities,
    /// [`crate::serving::Priority`]).  Within one class, `tenant` drives
    /// self-clocked weighted fair queueing: each submission gets a
    /// virtual finish tag `max(v, finish[tenant]) + cost / weight` and
    /// the class is kept in ascending-tag order, so a tenant flooding
    /// the queue advances its own tags far ahead and cannot starve a
    /// lighter (or heavier-weighted) tenant.  Policies still only decide
    /// *when* the head enters the engine — they never reorder, and
    /// nothing already admitted is displaced.
    pub fn enqueue_wfq(&mut self, cmd: EngineCmd, now: f64, prio: u8, tenant: u32) -> Vec<EngineCmd> {
        let (req_id, cost) = match &cmd {
            EngineCmd::SubmitAr(j) => (j.req_id, j.prompt.len() + j.sampling.max_new_tokens),
            // An imported sequence commits its resident prompt plus its
            // remaining generation budget, like a fresh AR submission.
            EngineCmd::SubmitKv(h) => (h.req_id, h.len + h.sampling.max_new_tokens),
            EngineCmd::SubmitDiffusion(j) => (j.req_id, j.steps.max(1)),
            EngineCmd::SubmitVocoder(j) => (j.req_id, j.tokens.len().max(1)),
            EngineCmd::SubmitEncode(j) => (j.req_id, j.frames.max(1)),
            EngineCmd::Upstream { req_id, .. } => {
                // Conditioning rows: buffer behind a queued submission of
                // the same request, otherwise flow straight to the engine.
                // (Queued chunks of the request don't need the rows —
                // only AR submissions consume them, and an AR request has
                // exactly one submission.)
                let req_id = *req_id;
                if let Some(p) = self.pending.iter_mut().find(|p| p.job.req_id == req_id) {
                    p.upstream.push(cmd);
                    return vec![];
                }
                self.stats.passthrough += 1;
                return vec![cmd];
            }
        };
        // Tag the submission (SCFQ: start from the later of the virtual
        // clock and the tenant's own last finish, advance by weighted
        // cost) and insert behind the last entry of higher priority or
        // of equal priority with an earlier-or-equal tag.  One tenant:
        // tags are monotonic, so this degenerates to stable FIFO within
        // a class (O(queue) worst case, O(1) for all-normal).
        let vft = self.virtual_clock.max(
            self.tenant_finish.get(&tenant).copied().unwrap_or(0.0),
        ) + cost as f64 / self.tenant_weight(tenant);
        self.tenant_finish.insert(tenant, vft);
        let pos = self
            .pending
            .iter()
            .rposition(|p| p.prio > prio || (p.prio == prio && p.vft <= vft))
            .map_or(0, |i| i + 1);
        self.pending.insert(
            pos,
            Pending {
                job: PendingJob { req_id, cost_tokens: cost },
                cmd,
                prio,
                vft,
                upstream: vec![],
                enqueued_at: now,
            },
        );
        self.stats.max_queue_depth = self.stats.max_queue_depth.max(self.pending.len());
        vec![]
    }

    /// Drop every pending submission of `req_id` (end-to-end
    /// cancellation; buffered conditioning rows die with them).
    /// Returns the number of submissions dropped.
    pub fn cancel(&mut self, req_id: u64) -> usize {
        let before = self.pending.len();
        self.pending.retain(|p| p.job.req_id != req_id);
        let dropped = before - self.pending.len();
        self.stats.cancelled += dropped as u64;
        dropped
    }

    /// Ask the policy which queued submissions to admit given the engine's
    /// occupancy; returns them (with any buffered conditioning) in queue
    /// order.
    pub fn ready(&mut self, view: &EngineView, now: f64) -> Vec<EngineCmd> {
        self.ready_with(view, now, |_, _| {})
    }

    /// [`ready`](Self::ready) with an observer called as `(req_id,
    /// queue_wait_s)` for every admission — the orchestrator's hook for
    /// emitting [`crate::metrics::Event::SchedAdmitted`].
    pub fn ready_with(
        &mut self,
        view: &EngineView,
        now: f64,
        mut on_admit: impl FnMut(u64, f64),
    ) -> Vec<EngineCmd> {
        let mut out = Vec::new();
        // Every policy admits at most `free_slots <= max_batch` jobs, so
        // a full engine needs no policy call and the job snapshot never
        // has to cover more than the head `max_batch` entries — keeping
        // this O(max_batch), not O(queue), on the hot path.
        if !self.pending.is_empty() && view.free_slots() > 0 {
            let jobs: Vec<PendingJob> = self
                .pending
                .iter()
                .take(view.max_batch.max(1))
                .map(|p| p.job.clone())
                .collect();
            let n = self.policy.admit(&jobs, view).min(self.pending.len());
            for _ in 0..n {
                let p = self.pending.pop_front().unwrap();
                // SCFQ virtual time follows the service order.
                self.virtual_clock = self.virtual_clock.max(p.vft);
                self.stats.admitted += 1;
                let wait = (now - p.enqueued_at).max(0.0);
                self.stats.queue_wait.push(wait);
                on_admit(p.job.req_id, wait);
                out.push(p.cmd);
                out.extend(p.upstream);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::ar::token_job;
    use crate::engine::SamplingParams;

    fn submit(req: u64, max_new: usize) -> EngineCmd {
        EngineCmd::SubmitAr(token_job(
            req,
            &[1, 2],
            SamplingParams { max_new_tokens: max_new, ..Default::default() },
        ))
    }

    fn upstream(req: u64) -> EngineCmd {
        EngineCmd::Upstream { req_id: req, rows: vec![0.5; 8], dim: 8, complete: false }
    }

    fn view(running: usize, max_batch: usize) -> EngineView {
        EngineView { running, max_batch, ..Default::default() }
    }

    #[test]
    fn upstream_is_buffered_until_admission() {
        let mut s = StageScheduler::new(Box::new(FifoPolicy), 0);
        assert!(s.enqueue(submit(1, 10), 0.0).is_empty());
        // Rows for the queued request must NOT pass through early.
        assert!(s.enqueue(upstream(1), 0.0).is_empty());
        let cmds = s.ready(&view(0, 4), 0.5);
        assert_eq!(cmds.len(), 2, "submission + buffered upstream");
        assert!(matches!(cmds[0], EngineCmd::SubmitAr(_)));
        assert!(matches!(cmds[1], EngineCmd::Upstream { .. }));
        // Later rows for the now-admitted request flow straight through.
        assert_eq!(s.enqueue(upstream(1), 1.0).len(), 1);
    }

    #[test]
    fn fifo_holds_queue_while_engine_busy() {
        let mut s = StageScheduler::new(Box::new(FifoPolicy), 0);
        s.enqueue(submit(1, 10), 0.0);
        s.enqueue(submit(2, 10), 0.0);
        assert!(s.ready(&view(3, 4), 0.1).is_empty());
        assert_eq!(s.ready(&view(0, 4), 0.2).len(), 2);
        assert_eq!(s.stats.admitted, 2);
        assert!(s.is_empty());
    }

    #[test]
    fn queue_depth_backpressure() {
        let mut s = StageScheduler::new(Box::new(FifoPolicy), 2);
        assert!(s.has_room());
        s.enqueue(submit(1, 1), 0.0);
        s.enqueue(submit(2, 1), 0.0);
        assert!(!s.has_room());
        s.ready(&view(0, 4), 0.1);
        assert!(s.has_room());
    }

    #[test]
    fn streaming_chunks_are_policy_gated_in_order() {
        let mut s = StageScheduler::new(Box::new(FifoPolicy), 0);
        let chunk = |req, idx, fin| {
            EngineCmd::SubmitVocoder(crate::engine::vocoder::VocoderJob {
                req_id: req,
                chunk_idx: idx,
                tokens: vec![1, 2, 3],
                final_chunk: fin,
            })
        };
        // Chunks of request 1 interleave with request 2's head chunk;
        // every chunk queues and admits in arrival order — request 1's
        // follow-up chunks get no bypass that would starve request 2.
        assert!(s.enqueue(chunk(1, 0, false), 0.0).is_empty());
        assert!(s.enqueue(chunk(1, 1, false), 0.0).is_empty());
        assert!(s.enqueue(chunk(2, 0, true), 0.0).is_empty());
        assert!(s.ready(&view(1, 4), 0.1).is_empty(), "FIFO waits for drain");
        let cmds = s.ready(&view(0, 4), 0.2);
        assert_eq!(cmds.len(), 3, "all three admitted together, in order");
        let ids: Vec<(u64, usize)> = cmds
            .iter()
            .map(|c| match c {
                EngineCmd::SubmitVocoder(j) => (j.req_id, j.chunk_idx),
                other => panic!("{other:?}"),
            })
            .collect();
        assert_eq!(ids, vec![(1, 0), (1, 1), (2, 0)]);
        assert_eq!(s.stats.admitted, 3, "each chunk consumes an admission");
    }

    #[test]
    fn priority_orders_the_pending_queue_stably() {
        let mut s = StageScheduler::new(Box::new(FifoPolicy), 0);
        s.enqueue_prio(submit(1, 1), 0.0, 1); // normal
        s.enqueue_prio(submit(2, 1), 0.0, 0); // low
        s.enqueue_prio(submit(3, 1), 0.0, 2); // high jumps both
        s.enqueue_prio(submit(4, 1), 0.0, 2); // high, FIFO behind 3
        s.enqueue_prio(submit(5, 1), 0.0, 1); // normal, behind 1, ahead of low
        let cmds = s.ready(&view(0, 8), 0.1);
        let ids: Vec<u64> = cmds
            .iter()
            .map(|c| match c {
                EngineCmd::SubmitAr(j) => j.req_id,
                other => panic!("{other:?}"),
            })
            .collect();
        assert_eq!(ids, vec![3, 4, 1, 5, 2]);
    }

    #[test]
    fn upstream_buffers_behind_a_priority_inserted_submission() {
        let mut s = StageScheduler::new(Box::new(FifoPolicy), 0);
        s.enqueue_prio(submit(1, 1), 0.0, 1);
        s.enqueue_prio(submit(2, 1), 0.0, 2); // inserted ahead of 1
        assert!(s.enqueue(upstream(1), 0.0).is_empty(), "rows buffer on req 1");
        let cmds = s.ready(&view(0, 8), 0.1);
        assert_eq!(cmds.len(), 3);
        assert!(matches!(&cmds[0], EngineCmd::SubmitAr(j) if j.req_id == 2));
        assert!(matches!(&cmds[1], EngineCmd::SubmitAr(j) if j.req_id == 1));
        assert!(matches!(&cmds[2], EngineCmd::Upstream { req_id: 1, .. }));
    }

    #[test]
    fn cancel_drops_every_pending_submission_of_the_request() {
        let mut s = StageScheduler::new(Box::new(FifoPolicy), 2);
        s.enqueue(submit(1, 1), 0.0);
        s.enqueue(submit(2, 1), 0.0);
        assert!(!s.has_room(), "queue-depth cap reached");
        assert_eq!(s.cancel(1), 1);
        assert_eq!(s.cancel(1), 0, "idempotent");
        assert!(s.has_room(), "cancellation frees queue room");
        assert_eq!(s.stats.cancelled, 1);
        let cmds = s.ready(&view(0, 4), 0.1);
        assert_eq!(cmds.len(), 1, "only the surviving request admits");
        assert!(matches!(&cmds[0], EngineCmd::SubmitAr(j) if j.req_id == 2));
        assert!(s.is_empty(), "queue drains after cancel + admit");
    }

    #[test]
    fn wfq_keeps_a_flooding_tenant_from_starving_a_weighted_one() {
        let mut s = StageScheduler::new(Box::new(FifoPolicy), 0);
        // Tenant 1 weighs 4x tenant 2 (index = tenant id; 0 = anonymous).
        s.set_tenant_weights(vec![1.0, 4.0, 1.0]);
        // The hot tenant floods the queue FIRST...
        for i in 0..8u64 {
            s.enqueue_wfq(submit(200 + i, 1), 0.0, 1, 2);
        }
        // ...then the weighted tenant shows up.
        for i in 0..8u64 {
            s.enqueue_wfq(submit(100 + i, 1), 0.0, 1, 1);
        }
        let cmds = s.ready(&view(0, 16), 0.1);
        let ids: Vec<u64> = cmds
            .iter()
            .map(|c| match c {
                EngineCmd::SubmitAr(j) => j.req_id,
                other => panic!("{other:?}"),
            })
            .collect();
        assert_eq!(ids.len(), 16, "everything still admits — WFQ reorders, never drops");
        assert!(ids[0] >= 100 && ids[0] < 200, "weighted tenant jumps the flood: {ids:?}");
        let heavy_in_first_8 = ids[..8].iter().filter(|&&id| id < 200).count();
        assert!(
            heavy_in_first_8 >= 6,
            "4x-weighted tenant should hold ~4/5 of the early slots, got {heavy_in_first_8} in {ids:?}"
        );
        // Within each tenant, arrival order is preserved.
        let t1: Vec<u64> = ids.iter().copied().filter(|&id| id < 200).collect();
        let t2: Vec<u64> = ids.iter().copied().filter(|&id| id >= 200).collect();
        assert_eq!(t1, (100..108).collect::<Vec<u64>>());
        assert_eq!(t2, (200..208).collect::<Vec<u64>>());
    }

    #[test]
    fn wfq_priority_classes_still_dominate_tenancy() {
        let mut s = StageScheduler::new(Box::new(FifoPolicy), 0);
        s.set_tenant_weights(vec![1.0, 8.0]);
        s.enqueue_wfq(submit(1, 1), 0.0, 1, 1); // normal, heavy tenant
        s.enqueue_wfq(submit(2, 1), 0.0, 2, 0); // high, anonymous
        let cmds = s.ready(&view(0, 4), 0.1);
        assert!(matches!(&cmds[0], EngineCmd::SubmitAr(j) if j.req_id == 2));
        assert!(matches!(&cmds[1], EngineCmd::SubmitAr(j) if j.req_id == 1));
    }

    #[test]
    fn wfq_single_tenant_stays_fifo_across_unequal_costs() {
        let mut s = StageScheduler::new(Box::new(FifoPolicy), 0);
        // A cheap job enqueued after an expensive one must NOT jump it
        // when both belong to the same tenant.
        s.enqueue_wfq(submit(1, 100), 0.0, 1, 0);
        s.enqueue_wfq(submit(2, 1), 0.0, 1, 0);
        let cmds = s.ready(&view(0, 4), 0.1);
        assert!(matches!(&cmds[0], EngineCmd::SubmitAr(j) if j.req_id == 1));
        assert!(matches!(&cmds[1], EngineCmd::SubmitAr(j) if j.req_id == 2));
    }

    #[test]
    fn wait_times_recorded() {
        let mut s = StageScheduler::new(
            Box::new(ContinuousBatchingPolicy { max_batch_tokens: 0 }),
            0,
        );
        s.enqueue(submit(1, 4), 1.0);
        s.ready(&view(0, 2), 3.5);
        assert_eq!(s.stats.queue_wait.len(), 1);
        assert!((s.stats.queue_wait.mean() - 2.5).abs() < 1e-9);
    }
}
