//! Per-stage batching policies (paper §3.3 "per-stage request batching").
//!
//! A [`BatchPolicy`] decides, between engine iterations, how many pending
//! jobs to move from the stage's admission queue into its engine.  The
//! decision point *is* the token boundary: engines are synchronous state
//! machines advanced by `step()`, so everything admitted here joins the
//! running batch at the next iteration, and finished sequences left the
//! batch during the previous one.
//!
//! Three concrete policies cover the stage kinds the paper evaluates:
//!
//! * [`ContinuousBatchingPolicy`] — AR stages.  Sequences join whenever a
//!   slot is free and the *max-batch-tokens* budget (the sum of token
//!   commitments of everything in flight) permits; they evict at token
//!   boundaries as they finish.  This is Orca-style continuous batching
//!   with vLLM's token-budget admission control on top.
//! * [`StepBatchingPolicy`] — diffusion stages.  Requests are grouped into
//!   step-aligned cohorts: a new job may only join while the running
//!   lanes are within `step_window` denoise steps of the start, so every
//!   trunk call serves lanes at (near-)matching timesteps — which keeps
//!   the batched `step.bN` executables full and the step-cache signal
//!   coherent.
//! * [`FifoPolicy`] — encoder / vocoder stages (and the static-batching
//!   baseline for AR stages).  Strict arrival order, drain-then-refill:
//!   a new batch is admitted only when the engine is empty.  For
//!   single-call stages this degenerates to pass-through; for AR stages
//!   it reproduces the classic convoy effect that continuous batching
//!   eliminates (measured in `benches/sched_batching.rs`).


/// What a pending job will cost the engine, as far as admission control is
/// concerned.  Built by the scheduler from the submission command.
#[derive(Debug, Clone)]
pub struct PendingJob {
    pub req_id: u64,
    /// Token commitment: prompt + generation budget for AR jobs, denoise
    /// steps for diffusion jobs, chunk frames for vocoder/encoder jobs.
    pub cost_tokens: usize,
}

/// Engine occupancy snapshot taken between iterations; the only state a
/// policy may base decisions on.
#[derive(Debug, Clone, Default)]
pub struct EngineView {
    /// Sequences / lanes / jobs currently in the engine (running or in
    /// its internal admission queue).
    pub running: usize,
    /// Batch capacity (`StageConfig::max_batch`).
    pub max_batch: usize,
    /// Sum of token commitments of everything in flight (AR stages).
    pub committed_tokens: usize,
    /// Per-lane current denoise step (diffusion stages; empty otherwise).
    pub lane_steps: Vec<usize>,
}

impl EngineView {
    pub fn free_slots(&self) -> usize {
        self.max_batch.saturating_sub(self.running)
    }
}

/// A per-stage batching policy.  `admit` returns how many jobs from the
/// *front* of the pending queue to submit now — policies shape batches by
/// timing, never by reordering, so per-stage FIFO fairness is preserved.
pub trait BatchPolicy: Send {
    fn name(&self) -> &'static str;

    /// How many of `pending` (front first) to admit given `view`.
    fn admit(&mut self, pending: &[PendingJob], view: &EngineView) -> usize;
}

/// Continuous batching: join whenever a slot is free and the token budget
/// allows (paper §3.3; vLLM/Orca lineage).
#[derive(Debug, Clone)]
pub struct ContinuousBatchingPolicy {
    /// In-flight token budget; 0 = unlimited (slot-bound only).
    pub max_batch_tokens: usize,
}

impl BatchPolicy for ContinuousBatchingPolicy {
    fn name(&self) -> &'static str {
        "continuous"
    }

    fn admit(&mut self, pending: &[PendingJob], view: &EngineView) -> usize {
        let mut committed = view.committed_tokens;
        let mut n = 0;
        for job in pending.iter().take(view.free_slots()) {
            if self.max_batch_tokens > 0
                && committed + job.cost_tokens > self.max_batch_tokens
                && committed > 0
            {
                // Budget full — wait for evictions.  (A single oversized
                // job is admitted into an empty engine rather than
                // deadlocking the queue.)
                break;
            }
            committed += job.cost_tokens;
            n += 1;
        }
        n
    }
}

/// Step-level batching for diffusion stages: group requests into cohorts
/// whose denoise steps match (within `step_window`).
#[derive(Debug, Clone)]
pub struct StepBatchingPolicy {
    /// A job may join while every running lane is at most this many steps
    /// into its schedule; otherwise it waits for the cohort to drain.
    pub step_window: usize,
}

impl BatchPolicy for StepBatchingPolicy {
    fn name(&self) -> &'static str {
        "step-level"
    }

    fn admit(&mut self, pending: &[PendingJob], view: &EngineView) -> usize {
        // Cohort alignment requires EVERY running lane to still be near
        // the start — gate on the deepest lane, not the youngest, or one
        // fresh lane would hold the window open forever.
        let aligned = match view.lane_steps.iter().max() {
            None => true, // empty engine: start a fresh cohort
            Some(&deepest) => deepest <= self.step_window,
        };
        if !aligned {
            return 0;
        }
        pending.len().min(view.free_slots())
    }
}

/// Strict FIFO with drain-then-refill batches (static batching).
#[derive(Debug, Clone, Default)]
pub struct FifoPolicy;

impl BatchPolicy for FifoPolicy {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn admit(&mut self, pending: &[PendingJob], view: &EngineView) -> usize {
        if view.running > 0 {
            return 0;
        }
        pending.len().min(view.max_batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jobs(costs: &[usize]) -> Vec<PendingJob> {
        costs
            .iter()
            .enumerate()
            .map(|(i, &c)| PendingJob { req_id: i as u64, cost_tokens: c })
            .collect()
    }

    #[test]
    fn continuous_joins_into_free_slots() {
        let mut p = ContinuousBatchingPolicy { max_batch_tokens: 0 };
        let view = EngineView { running: 1, max_batch: 4, ..Default::default() };
        assert_eq!(p.admit(&jobs(&[10, 10, 10, 10]), &view), 3);
    }

    #[test]
    fn continuous_respects_token_budget() {
        let mut p = ContinuousBatchingPolicy { max_batch_tokens: 100 };
        let view = EngineView {
            running: 1,
            max_batch: 8,
            committed_tokens: 60,
            ..Default::default()
        };
        // 60 committed: a 30-token job fits, the following 30-token job
        // would cross 100.
        assert_eq!(p.admit(&jobs(&[30, 30]), &view), 1);
    }

    #[test]
    fn continuous_never_starves_oversized_job() {
        let mut p = ContinuousBatchingPolicy { max_batch_tokens: 100 };
        let view = EngineView { running: 0, max_batch: 8, ..Default::default() };
        assert_eq!(p.admit(&jobs(&[500]), &view), 1);
    }

    #[test]
    fn step_policy_gates_on_cohort_alignment() {
        let mut p = StepBatchingPolicy { step_window: 2 };
        let empty = EngineView { running: 0, max_batch: 4, ..Default::default() };
        assert_eq!(p.admit(&jobs(&[8, 8]), &empty), 2);
        let young = EngineView {
            running: 2,
            max_batch: 4,
            lane_steps: vec![1, 2],
            ..Default::default()
        };
        assert_eq!(p.admit(&jobs(&[8]), &young), 1);
        let old = EngineView {
            running: 2,
            max_batch: 4,
            lane_steps: vec![5, 7],
            ..Default::default()
        };
        assert_eq!(p.admit(&jobs(&[8]), &old), 0, "mid-flight cohort must not be joined");
        // One young lane must NOT hold the window open while another lane
        // is deep into denoising (gate is on the deepest lane).
        let mixed = EngineView {
            running: 2,
            max_batch: 4,
            lane_steps: vec![1, 9],
            ..Default::default()
        };
        assert_eq!(p.admit(&jobs(&[8]), &mixed), 0);
    }

    #[test]
    fn fifo_drains_before_refilling() {
        let mut p = FifoPolicy;
        let busy = EngineView { running: 1, max_batch: 4, ..Default::default() };
        assert_eq!(p.admit(&jobs(&[1, 1]), &busy), 0);
        let idle = EngineView { running: 0, max_batch: 4, ..Default::default() };
        assert_eq!(p.admit(&jobs(&[1; 6]), &idle), 4);
    }
}
