//! Stage → device allocation (paper §3.3 "flexible GPU allocation").
//!
//! [`StageAllocator`] turns the per-stage `devices` / `replicas` /
//! `max_batch` / `sched` fields of a [`PipelineConfig`] into a validated
//! [`AllocationPlan`]: one [`StageAssignment`] per stage with the batching
//! policy resolved and a device group packed for every engine replica,
//! plus a per-device load map.  The orchestrator builds the plan before
//! spawning stage threads, so a mis-configured pipeline fails at
//! construction time instead of mid-run — the same admission role the
//! real system's allocator plays next to the memory reservation in
//! [`crate::stage_graph::StageGraph::reserve_memory`].
//!
//! Replica packing: replica 0 honors the configured `devices` placement
//! verbatim.  Each further replica gets a group of the same TP degree on
//! the currently least-loaded devices (load = replica-placements already
//! made, seeded with every stage's configured placement), so hot-stage
//! replicas spread across the pool instead of stacking on one
//! accelerator.  Whether the packed placement *fits* is decided by the
//! per-replica memory reservation, not here.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::config::{PipelineConfig, SchedPolicyKind, StageKind};
use crate::device::DeviceId;
use crate::runtime::Artifacts;

/// One stage's resolved scheduling assignment.
#[derive(Debug, Clone)]
pub struct StageAssignment {
    pub stage: String,
    pub kind: StageKind,
    /// Replica 0's device placement (len > 1 = tensor parallel across the
    /// group) — kept as the "primary" group for single-replica callers.
    pub devices: Vec<DeviceId>,
    /// Engine replicas serving the stage (>= 1).
    pub replicas: usize,
    /// Device group per replica; `replica_devices[0] == devices`, every
    /// group has the same TP degree.
    pub replica_devices: Vec<Vec<DeviceId>>,
    /// Compute share per replica in milli-GPUs (1000 = a whole device;
    /// less = a fractional slot under [`crate::gpu_share`]).
    pub compute_milli: u32,
    /// Resolved batching policy (never [`SchedPolicyKind::Auto`]).
    pub policy: SchedPolicyKind,
    pub max_batch: usize,
    /// In-flight token budget for continuous batching (0 = unlimited).
    pub max_batch_tokens: usize,
    /// Admission-queue depth cap (0 = unbounded); beyond it the stage
    /// thread stops pulling from its connectors (backpressure).
    pub queue_depth: usize,
    /// Cohort-alignment window for step-level batching.
    pub step_window: usize,
}

impl StageAssignment {
    /// Instantiate the resolved batching policy.
    pub fn make_policy(&self) -> Box<dyn super::BatchPolicy> {
        match self.policy {
            SchedPolicyKind::Continuous => Box::new(super::ContinuousBatchingPolicy {
                max_batch_tokens: self.max_batch_tokens,
            }),
            SchedPolicyKind::StepLevel => {
                Box::new(super::StepBatchingPolicy { step_window: self.step_window })
            }
            SchedPolicyKind::Fifo => Box::new(super::FifoPolicy),
            SchedPolicyKind::Auto => unreachable!("plan() resolves Auto"),
        }
    }
}

/// A validated allocation for a whole pipeline.
#[derive(Debug, Clone)]
pub struct AllocationPlan {
    assignments: Vec<StageAssignment>,
    /// Stages sharing each device (time-multiplexed on the simulated pool).
    load: HashMap<DeviceId, Vec<String>>,
}

/// Pick a device group of `tp` members on the currently least-loaded
/// devices (`load[d]` = replica placements already made on device `d`).
/// Shared by [`StageAllocator::plan`]'s replica packing and the serving
/// runtime's incremental scale-up path, so static and elastic placements
/// follow the same policy.  Does NOT mutate `load` — callers commit the
/// group with [`commit_group`] once admission (memory) succeeds.
pub fn pack_group(load: &[usize], tp: usize) -> Vec<DeviceId> {
    let mut order: Vec<usize> = (0..load.len()).collect();
    order.sort_by_key(|&d| (load[d], d));
    order.iter().take(tp).map(|&d| DeviceId(d)).collect()
}

/// Record a packed group in the load map (scale-up commit).
pub fn commit_group(load: &mut [usize], group: &[DeviceId]) {
    for g in group {
        load[g.0] += 1;
    }
}

/// Remove a group from the load map (replica retired).
pub fn release_group(load: &mut [usize], group: &[DeviceId]) {
    for g in group {
        load[g.0] = load[g.0].saturating_sub(1);
    }
}

impl AllocationPlan {
    /// Assignment for stage index `i` (stage order of the config).
    pub fn assignment(&self, i: usize) -> &StageAssignment {
        &self.assignments[i]
    }

    /// Per-device replica-placement counts implied by this plan (the
    /// seed state for incremental re-packing at runtime).
    pub fn device_load(&self, n_devices: usize) -> Vec<usize> {
        let mut load = vec![0usize; n_devices];
        for a in &self.assignments {
            for group in &a.replica_devices {
                commit_group(&mut load, group);
            }
        }
        load
    }

    /// Per-device compute-milli ledger seeded from every planned
    /// replica — the serving session and autoscaler start from this to
    /// pack further fractional replicas into spare slivers.
    pub fn device_milli(&self, n_devices: usize) -> crate::gpu_share::MilliLedger {
        let mut m = crate::gpu_share::MilliLedger::new(n_devices);
        for a in &self.assignments {
            for group in &a.replica_devices {
                for g in group {
                    m.commit(g.0, a.compute_milli);
                }
            }
        }
        m
    }

    /// Total device slots this plan occupies (Σ replicas × TP degree) —
    /// what the autoscaler's GPU budget counts.
    pub fn device_slots(&self) -> usize {
        self.assignments.iter().map(|a| a.replicas * a.devices.len()).sum()
    }

    pub fn by_name(&self, stage: &str) -> Option<&StageAssignment> {
        self.assignments.iter().find(|a| a.stage == stage)
    }

    pub fn assignments(&self) -> &[StageAssignment] {
        &self.assignments
    }

    /// Names of the stages placed on `device`.
    pub fn stages_on(&self, device: DeviceId) -> &[String] {
        self.load.get(&device).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

/// Builds [`AllocationPlan`]s from pipeline configs.
pub struct StageAllocator<'a> {
    config: &'a PipelineConfig,
}

impl<'a> StageAllocator<'a> {
    pub fn new(config: &'a PipelineConfig) -> Self {
        Self { config }
    }

    /// Validate and resolve the allocation.  `artifacts`, when given, adds
    /// model-aware checks (compiled batch buckets exist for the stage's
    /// engine family, so a mis-batched stage fails here instead of on its
    /// engine thread).
    pub fn plan(&self, artifacts: Option<&Artifacts>) -> Result<AllocationPlan> {
        // Structural checks (non-empty device groups, placement bounds,
        // name uniqueness, replica/routing sanity, ...) live in one place.
        self.config.validate()?;
        let mut assignments = Vec::with_capacity(self.config.stages.len());
        let mut load: HashMap<DeviceId, Vec<String>> = HashMap::new();
        // Replica packing pressure: placements per device, seeded with
        // every stage's configured (replica 0) group so extra replicas
        // route around the whole pipeline's baseline placement.
        let mut dev_load = vec![0usize; self.config.n_devices];
        // Compute-share pressure for fractional replicas: milli-GPUs
        // carved per device, seeded with every stage's configured
        // placement (whole stages charge the full 1000 per group member).
        let mut milli = crate::gpu_share::MilliLedger::new(self.config.n_devices);
        for s in &self.config.stages {
            for &d in &s.devices {
                dev_load[d] += 1;
                milli.commit(d, s.compute_milli);
            }
        }
        for s in &self.config.stages {
            let mut seen = std::collections::HashSet::new();
            for &d in &s.devices {
                if !seen.insert(d) {
                    bail!("stage `{}`: device {d} listed twice in its TP group", s.name);
                }
            }
            let policy = s.sched.policy.resolve(s.kind);
            match (policy, s.kind) {
                (SchedPolicyKind::Continuous, StageKind::Ar) => {}
                (SchedPolicyKind::Continuous, k) => bail!(
                    "stage `{}`: continuous batching requires an AR stage, got `{}`",
                    s.name,
                    k.name()
                ),
                (SchedPolicyKind::StepLevel, StageKind::Dit) => {}
                (SchedPolicyKind::StepLevel, k) => bail!(
                    "stage `{}`: step-level batching requires a DiT stage, got `{}`",
                    s.name,
                    k.name()
                ),
                _ => {}
            }
            if s.sched.max_batch_tokens > 0 && s.kind != StageKind::Ar {
                bail!(
                    "stage `{}`: max_batch_tokens only applies to AR stages",
                    s.name
                );
            }
            if let Some(art) = artifacts {
                // Fail-fast check: the stage's hot entry family must have
                // compiled buckets, or its engine would die on its thread.
                // (Vocoder/encoder entry families are model-specific and
                // always compiled with their full bucket set.)
                let family = match s.kind {
                    StageKind::Ar => Some("decode"),
                    StageKind::Dit => Some("step"),
                    _ => None,
                };
                if let Some(fam) = family {
                    let model = art.model(&s.model)?;
                    if model.buckets(fam).is_empty() {
                        bail!(
                            "stage `{}`: model `{}` has no compiled `{fam}` buckets",
                            s.name,
                            s.model
                        );
                    }
                }
            }
            let devices: Vec<DeviceId> = s.devices.iter().map(|&d| DeviceId(d)).collect();
            // Pack one device group per replica: replica 0 is the
            // configured placement; each further replica takes the
            // currently least-loaded devices at the same TP degree.
            let mut replica_devices = Vec::with_capacity(s.replicas);
            replica_devices.push(devices.clone());
            for _ in 1..s.replicas {
                // Fractional replicas pack by spare milli first (filling
                // partially-carved devices), falling back to whole-slot
                // packing when no device has compute headroom left.
                let fractional = s.compute_milli < crate::gpu_share::DEVICE_MILLI;
                let group = match milli.pack(s.compute_milli) {
                    Some(d) if fractional => vec![DeviceId(d)],
                    _ => pack_group(&dev_load, devices.len()),
                };
                commit_group(&mut dev_load, &group);
                for g in &group {
                    milli.commit(g.0, s.compute_milli);
                }
                replica_devices.push(group);
            }
            for group in &replica_devices {
                for &d in group {
                    load.entry(d).or_default().push(s.name.clone());
                }
            }
            assignments.push(StageAssignment {
                stage: s.name.clone(),
                kind: s.kind,
                devices,
                replicas: s.replicas,
                replica_devices,
                compute_milli: s.compute_milli,
                policy,
                max_batch: s.max_batch,
                max_batch_tokens: s.sched.max_batch_tokens,
                queue_depth: s.sched.queue_depth,
                step_window: s.sched.step_window,
            });
        }
        Ok(AllocationPlan { assignments, load })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn plans_all_presets() {
        for p in presets::all() {
            let plan = StageAllocator::new(&p).plan(None).unwrap();
            assert_eq!(plan.assignments().len(), p.stages.len());
            for a in plan.assignments() {
                assert_ne!(a.policy, SchedPolicyKind::Auto, "{}: unresolved policy", a.stage);
            }
        }
    }

    #[test]
    fn auto_policy_resolves_by_kind() {
        let plan = StageAllocator::new(&presets::qwen25_omni()).plan(None).unwrap();
        assert_eq!(plan.by_name("thinker").unwrap().policy, SchedPolicyKind::Continuous);
        assert_eq!(plan.by_name("talker").unwrap().policy, SchedPolicyKind::Continuous);
        assert_eq!(plan.by_name("vocoder").unwrap().policy, SchedPolicyKind::StepLevel);
    }

    #[test]
    fn rejects_duplicate_device_in_group() {
        let mut p = presets::qwen3_omni();
        p.stages[0].devices = vec![0, 0];
        assert!(StageAllocator::new(&p).plan(None).is_err());
    }

    #[test]
    fn rejects_out_of_range_device() {
        let mut p = presets::qwen3_omni();
        p.stages[1].devices = vec![9];
        assert!(StageAllocator::new(&p).plan(None).is_err());
    }

    #[test]
    fn rejects_policy_kind_mismatch() {
        let mut p = presets::qwen25_omni();
        // Step-level batching on the (AR) thinker stage is invalid.
        p.stages[0].sched.policy = SchedPolicyKind::StepLevel;
        assert!(StageAllocator::new(&p).plan(None).is_err());
        // Continuous batching on the (DiT) vocoder stage is invalid.
        let mut p = presets::qwen25_omni();
        p.stages[2].sched.policy = SchedPolicyKind::Continuous;
        assert!(StageAllocator::new(&p).plan(None).is_err());
    }

    #[test]
    fn single_replica_assignments_are_unchanged() {
        let plan = StageAllocator::new(&presets::qwen3_omni()).plan(None).unwrap();
        for a in plan.assignments() {
            assert_eq!(a.replicas, 1);
            assert_eq!(a.replica_devices.len(), 1);
            assert_eq!(a.replica_devices[0], a.devices);
        }
    }

    #[test]
    fn replicas_pack_onto_least_loaded_devices() {
        // qwen3-omni baseline load: dev0 {thinker.tp0, vocoder}, dev1
        // {thinker.tp1, talker}.  A second talker replica must land on the
        // less-loaded... both are at 2, so the tie breaks to device 0 —
        // NOT stack on the talker's own device 1.
        let mut p = presets::qwen3_omni();
        p.stages.iter_mut().find(|s| s.name == "talker").unwrap().replicas = 2;
        let plan = StageAllocator::new(&p).plan(None).unwrap();
        let talker = plan.by_name("talker").unwrap();
        assert_eq!(talker.replicas, 2);
        assert_eq!(talker.replica_devices[0], vec![DeviceId(1)], "replica 0 honors config");
        assert_eq!(talker.replica_devices[1], vec![DeviceId(0)], "replica 1 spreads");
        // The load map sees both replicas.
        assert!(plan.stages_on(DeviceId(0)).contains(&"talker".to_string()));
        assert!(plan.stages_on(DeviceId(1)).contains(&"talker".to_string()));
    }

    #[test]
    fn tp_replicas_keep_their_degree() {
        // A TP-2 stage replicated 3x on a 4-device pool: every replica
        // group has 2 distinct devices.
        let mut p = presets::qwen3_omni();
        p.n_devices = 4;
        p.stages[0].replicas = 3; // thinker on {0,1}
        let plan = StageAllocator::new(&p).plan(None).unwrap();
        let thinker = plan.by_name("thinker").unwrap();
        assert_eq!(thinker.replica_devices.len(), 3);
        for group in &thinker.replica_devices {
            assert_eq!(group.len(), 2);
            assert_ne!(group[0], group[1]);
        }
        // First packed replica prefers the empty devices {2,3}.
        assert_eq!(thinker.replica_devices[1], vec![DeviceId(2), DeviceId(3)]);
    }

    #[test]
    fn fractional_replicas_pack_by_spare_milli() {
        // Branching preset seed: dev0 = encoder 300 + vocoder 300 (600
        // milli), dev1 = thinker + talker (whole), dev2 = imagegen
        // (whole).  A second encoder replica fits in dev0's headroom, so
        // it co-resides there — whole-slot packing would have sent it to
        // the least-loaded dev2 and wasted a whole device.
        let mut p = presets::qwen3_omni_branching();
        p.stages.iter_mut().find(|s| s.name == "encoder").unwrap().replicas = 2;
        let plan = StageAllocator::new(&p).plan(None).unwrap();
        let enc = plan.by_name("encoder").unwrap();
        assert_eq!(enc.compute_milli, 300);
        assert_eq!(enc.replica_devices[0], vec![DeviceId(0)]);
        assert_eq!(enc.replica_devices[1], vec![DeviceId(0)], "packs into spare milli");
        // Whole stages carry the full share in their assignment.
        assert_eq!(plan.by_name("thinker").unwrap().compute_milli, 1000);
    }

    #[test]
    fn fractional_replicas_fall_back_to_whole_packing_when_full() {
        // Carve the headroom away: a 900-milli encoder leaves no device
        // with room for a second 900 slot, so the extra replica falls
        // back to least-loaded whole-slot packing (dev2 holds only the
        // imagegen placement).
        let mut p = presets::qwen3_omni_branching();
        let enc = p.stages.iter_mut().find(|s| s.name == "encoder").unwrap();
        enc.compute_milli = 700;
        enc.replicas = 2;
        let plan = StageAllocator::new(&p).plan(None).unwrap();
        let enc = plan.by_name("encoder").unwrap();
        assert_eq!(enc.replica_devices[1], vec![DeviceId(2)], "no spare milli anywhere");
    }

    #[test]
    fn pack_release_roundtrip_keeps_load_consistent() {
        // The elastic scale-up/down path: pack on least-loaded devices,
        // commit, then release back to the pre-pack state.
        let mut load = vec![2usize, 0, 1, 0];
        let g = pack_group(&load, 2);
        assert_eq!(g, vec![DeviceId(1), DeviceId(3)], "least-loaded first, index tie-break");
        commit_group(&mut load, &g);
        assert_eq!(load, vec![2, 1, 1, 1]);
        release_group(&mut load, &g);
        assert_eq!(load, vec![2, 0, 1, 0]);
    }

    #[test]
    fn plan_device_load_matches_replica_placements() {
        let plan = StageAllocator::new(&presets::qwen3_omni_replicated()).plan(None).unwrap();
        // thinker TP {0,1}, talker {1} + packed replica, vocoder {0}.
        let load = plan.device_load(2);
        assert_eq!(load.iter().sum::<usize>(), plan.device_slots());
        assert_eq!(plan.device_slots(), 5, "tp2 thinker + 2x talker + vocoder");
    }

    #[test]
    fn device_load_map_tracks_sharing() {
        let plan = StageAllocator::new(&presets::qwen25_omni()).plan(None).unwrap();
        // Paper placement: thinker TP {0,1}, talker {1}, vocoder {0}.
        assert_eq!(plan.stages_on(DeviceId(0)), ["thinker".to_string(), "vocoder".to_string()]);
        assert_eq!(plan.stages_on(DeviceId(1)), ["thinker".to_string(), "talker".to_string()]);
    }
}
