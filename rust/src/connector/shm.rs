//! POSIX shared-memory payload plane (single-node large transfers,
//! paper Table 1 "Shared Memory" row).
//!
//! Uses `shm_open`/`mmap` directly through `libc` — real shared memory,
//! not a file copy — so the measured latency is representative.

use anyhow::{bail, Result};

/// Create a segment, copy `bytes` into it, close the mapping (the name
/// persists until unlink).
pub fn write_segment(name: &str, bytes: &[u8]) -> Result<()> {
    unsafe {
        let cname = std::ffi::CString::new(name)?;
        let fd = libc::shm_open(
            cname.as_ptr(),
            libc::O_CREAT | libc::O_RDWR | libc::O_EXCL,
            0o600,
        );
        if fd < 0 {
            bail!("shm_open({name}) failed: {}", std::io::Error::last_os_error());
        }
        if libc::ftruncate(fd, bytes.len() as libc::off_t) != 0 {
            libc::close(fd);
            libc::shm_unlink(cname.as_ptr());
            bail!("ftruncate failed: {}", std::io::Error::last_os_error());
        }
        let ptr = libc::mmap(
            std::ptr::null_mut(),
            bytes.len(),
            libc::PROT_WRITE,
            libc::MAP_SHARED,
            fd,
            0,
        );
        libc::close(fd);
        if ptr == libc::MAP_FAILED {
            libc::shm_unlink(cname.as_ptr());
            bail!("mmap failed: {}", std::io::Error::last_os_error());
        }
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), ptr as *mut u8, bytes.len());
        libc::munmap(ptr, bytes.len());
    }
    Ok(())
}

/// Map a segment read-only and copy it out.
pub fn read_segment(name: &str, len: usize) -> Result<Vec<u8>> {
    unsafe {
        let cname = std::ffi::CString::new(name)?;
        let fd = libc::shm_open(cname.as_ptr(), libc::O_RDONLY, 0);
        if fd < 0 {
            bail!("shm_open({name}) for read failed: {}", std::io::Error::last_os_error());
        }
        let ptr = libc::mmap(std::ptr::null_mut(), len, libc::PROT_READ, libc::MAP_SHARED, fd, 0);
        libc::close(fd);
        if ptr == libc::MAP_FAILED {
            bail!("mmap for read failed: {}", std::io::Error::last_os_error());
        }
        let mut out = vec![0u8; len];
        std::ptr::copy_nonoverlapping(ptr as *const u8, out.as_mut_ptr(), len);
        libc::munmap(ptr, len);
        Ok(out)
    }
}

pub fn unlink(name: &str) {
    if let Ok(cname) = std::ffi::CString::new(name) {
        unsafe {
            libc::shm_unlink(cname.as_ptr());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_unlink() {
        let name = format!("/omni_shm_test_{}", std::process::id());
        let data: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
        write_segment(&name, &data).unwrap();
        let got = read_segment(&name, data.len()).unwrap();
        assert_eq!(got, data);
        unlink(&name);
        assert!(read_segment(&name, data.len()).is_err());
    }

    #[test]
    fn duplicate_name_rejected() {
        let name = format!("/omni_shm_dup_{}", std::process::id());
        write_segment(&name, b"abc").unwrap();
        assert!(write_segment(&name, b"xyz").is_err());
        unlink(&name);
    }
}
