//! POSIX shared-memory payload plane (single-node large transfers,
//! paper Table 1 "Shared Memory" row).
//!
//! POSIX `shm_open` objects are files on the `/dev/shm` tmpfs; the offline
//! registry has no `libc` crate, so this module manipulates those objects
//! directly through `std::fs` instead of the `shm_open`/`mmap` FFI.  On
//! Linux the segments are identical kernel objects (memory-backed, never
//! touch disk) and the producer/consumer copies match what the FFI path
//! performed, so the measured latency stays representative; hosts without
//! `/dev/shm` fall back to the system temp dir.  Segment names follow the
//! `shm_open` convention of a single leading `/`.

use std::io::{ErrorKind, Read, Write};
use std::path::PathBuf;
use std::sync::OnceLock;

use anyhow::{bail, Result};

/// Where POSIX shm objects live on Linux; non-Linux POSIX hosts (no
/// `/dev/shm`) fall back to the system temp dir so the connector keeps
/// the portability of the old `shm_open` path (macOS temp dirs are
/// commonly memory-ish and always present).
fn shm_dir() -> &'static PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dev_shm = PathBuf::from("/dev/shm");
        if dev_shm.is_dir() {
            dev_shm
        } else {
            std::env::temp_dir()
        }
    })
}

fn segment_path(name: &str) -> PathBuf {
    // `shm_open("/foo")` creates `<shm dir>/foo`.
    shm_dir().join(name.trim_start_matches('/'))
}

/// Create a segment and copy `bytes` into it (the name persists until
/// [`unlink`]).  Like `shm_open(O_CREAT | O_EXCL)`, an existing segment
/// with the same name is an error.
pub fn write_segment(name: &str, bytes: &[u8]) -> Result<()> {
    let path = segment_path(name);
    let mut f = match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
        Ok(f) => f,
        Err(e) if e.kind() == ErrorKind::AlreadyExists => {
            bail!("shm segment `{name}` already exists");
        }
        Err(e) => bail!("creating shm segment `{name}`: {e}"),
    };
    if let Err(e) = f.write_all(bytes) {
        // Mirror the shm_open-path cleanup: never leave a partial segment
        // behind — a retry of the same name must not hit `already exists`
        // and a consumer must not read a short blob.
        drop(f);
        unlink(name);
        bail!("writing shm segment `{name}`: {e}");
    }
    Ok(())
}

/// Read a segment's first `len` bytes back out.
pub fn read_segment(name: &str, len: usize) -> Result<Vec<u8>> {
    let path = segment_path(name);
    let mut f = match std::fs::File::open(&path) {
        Ok(f) => f,
        Err(e) => bail!("opening shm segment `{name}` for read: {e}"),
    };
    let mut out = vec![0u8; len];
    f.read_exact(&mut out)
        .map_err(|e| anyhow::anyhow!("shm segment `{name}` shorter than {len} bytes: {e}"))?;
    Ok(out)
}

/// Remove a segment's name (best-effort, like `shm_unlink`).
pub fn unlink(name: &str) {
    let _ = std::fs::remove_file(segment_path(name));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_unlink() {
        let name = format!("/omni_shm_test_{}", std::process::id());
        let data: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
        write_segment(&name, &data).unwrap();
        let got = read_segment(&name, data.len()).unwrap();
        assert_eq!(got, data);
        unlink(&name);
        assert!(read_segment(&name, data.len()).is_err());
    }

    #[test]
    fn duplicate_name_rejected() {
        let name = format!("/omni_shm_dup_{}", std::process::id());
        write_segment(&name, b"abc").unwrap();
        assert!(write_segment(&name, b"xyz").is_err());
        unlink(&name);
    }
}
