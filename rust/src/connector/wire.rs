//! Wire formats for payloads crossing shm/TCP connectors.
//!
//! Two frames, each with its own magic:
//!
//! **StageItem frame** (`OMNI`), little-endian:
//! `magic u32 | req_id u64 | flags u8 | n_tensors u32 |`
//! per tensor: `name_len u32 | name bytes | blob_len u64 | tensor blob`
//! (tensor blob as produced by [`HostTensor::to_bytes`]).
//!
//! **KvHandoff frame** (`OKVH`), little-endian — the KV-transfer
//! subsystem's serialized sequence state (see [`crate::kv_transfer`]):
//! header fields, block accounting, hidden row, KV payload, and a
//! trailing FNV-1a checksum over everything before it.  Truncated or
//! corrupted frames must decode to an error, never panic — stage threads
//! surface the error and the run fails cleanly.

use anyhow::{bail, Result};

use crate::engine::{SamplingParams, StageItem};
use crate::kv_cache::KvSeqExport;
use crate::kv_transfer::KvHandoff;
use crate::runtime::HostTensor;

const MAGIC: u32 = 0x4F4D4E49; // "OMNI"
const KV_MAGIC: u32 = 0x4F4B5648; // "OKVH"
const KV_VERSION: u8 = 1;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF29CE484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

pub fn encode(item: &StageItem) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + item.payload_bytes());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&item.req_id.to_le_bytes());
    out.push(item.finished as u8);
    out.extend_from_slice(&(item.tensors.len() as u32).to_le_bytes());
    for (name, t) in &item.tensors {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        let blob = t.to_bytes();
        out.extend_from_slice(&(blob.len() as u64).to_le_bytes());
        out.extend_from_slice(&blob);
    }
    out
}

pub fn decode(bytes: &[u8]) -> Result<StageItem> {
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > bytes.len() {
            bail!("wire: truncated at {} (+{n} > {})", *pos, bytes.len());
        }
        let s = &bytes[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let magic = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
    if magic != MAGIC {
        bail!("wire: bad magic {magic:#x}");
    }
    let req_id = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
    let finished = take(&mut pos, 1)?[0] != 0;
    let n = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let mut item = StageItem::new(req_id);
    item.finished = finished;
    for _ in 0..n {
        let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
            .map_err(|_| anyhow::anyhow!("wire: non-utf8 tensor name"))?;
        let blob_len = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
        let t = HostTensor::from_bytes(take(&mut pos, blob_len)?)?;
        item.tensors.insert(name, t);
    }
    Ok(item)
}

// ---------------------------------------------------------------------
// KvHandoff frame
// ---------------------------------------------------------------------

pub fn encode_kv(h: &KvHandoff) -> Vec<u8> {
    let mut out = Vec::with_capacity(96 + h.hidden.len() * 4 + h.kv.len() * 4);
    out.extend_from_slice(&KV_MAGIC.to_le_bytes());
    out.push(KV_VERSION);
    out.extend_from_slice(&h.req_id.to_le_bytes());
    out.extend_from_slice(&(h.len as u64).to_le_bytes());
    out.extend_from_slice(&h.first_token.to_le_bytes());
    out.extend_from_slice(&(h.sampling.max_new_tokens as u64).to_le_bytes());
    out.extend_from_slice(&h.sampling.temperature.to_le_bytes());
    out.extend_from_slice(&(h.sampling.top_k as u64).to_le_bytes());
    out.push(h.sampling.ignore_eos as u8);
    out.extend_from_slice(&h.sampling.seed.to_le_bytes());
    out.extend_from_slice(&h.prng_state.to_le_bytes());
    for dim in [h.n_layers, h.n_heads, h.d_head] {
        out.extend_from_slice(&(dim as u64).to_le_bytes());
    }
    out.extend_from_slice(&h.blocks.block_size.to_le_bytes());
    out.extend_from_slice(&(h.blocks.full_hashes.len() as u64).to_le_bytes());
    for hash in &h.blocks.full_hashes {
        match hash {
            Some(v) => {
                out.push(1);
                out.extend_from_slice(&v.to_le_bytes());
            }
            None => out.push(0),
        }
    }
    out.extend_from_slice(&(h.hidden.len() as u64).to_le_bytes());
    for x in &h.hidden {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out.extend_from_slice(&(h.kv.len() as u64).to_le_bytes());
    for x in &h.kv {
        out.extend_from_slice(&x.to_le_bytes());
    }
    let sum = fnv1a(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

pub fn decode_kv(bytes: &[u8]) -> Result<KvHandoff> {
    // Checksum first: a flipped byte anywhere in the frame is caught even
    // when it lands in f32 payload data a structural check cannot see.
    if bytes.len() < 8 {
        bail!("kv wire: frame too short ({} bytes)", bytes.len());
    }
    let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let declared = u64::from_le_bytes(sum_bytes.try_into().unwrap());
    if fnv1a(body) != declared {
        bail!("kv wire: checksum mismatch (corrupt frame)");
    }
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > body.len() {
            bail!("kv wire: truncated at {} (+{n} > {})", *pos, body.len());
        }
        let s = &body[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let magic = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
    if magic != KV_MAGIC {
        bail!("kv wire: bad magic {magic:#x}");
    }
    let version = take(&mut pos, 1)?[0];
    if version != KV_VERSION {
        bail!("kv wire: unsupported version {version}");
    }
    let req_id = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
    let len = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
    let first_token = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
    let max_new_tokens = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
    let temperature = f32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
    let top_k = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
    let ignore_eos = take(&mut pos, 1)?[0] != 0;
    let seed = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
    let prng_state = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
    let n_layers = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
    let n_heads = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
    let d_head = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
    let block_size = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
    let n_full = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
    // Bound by the frame size before allocating (a corrupt count must not
    // OOM; each entry is at least 1 byte).
    if n_full > body.len() - pos {
        bail!("kv wire: {n_full} block hashes cannot fit the remaining frame");
    }
    let mut full_hashes = Vec::with_capacity(n_full);
    for _ in 0..n_full {
        let flag = take(&mut pos, 1)?[0];
        full_hashes.push(match flag {
            0 => None,
            1 => Some(u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap())),
            other => bail!("kv wire: bad hash flag {other}"),
        });
    }
    let read_f32s = |pos: &mut usize, label: &str| -> Result<Vec<f32>> {
        let n = u64::from_le_bytes(take(&mut *pos, 8)?.try_into().unwrap()) as usize;
        if n.checked_mul(4).map_or(true, |b| b > body.len() - *pos) {
            bail!("kv wire: {label} length {n} exceeds the remaining frame");
        }
        Ok(take(&mut *pos, n * 4)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    };
    let hidden = read_f32s(&mut pos, "hidden")?;
    let kv = read_f32s(&mut pos, "kv")?;
    if pos != body.len() {
        bail!("kv wire: {} trailing bytes after payload", body.len() - pos);
    }
    let h = KvHandoff {
        req_id,
        len,
        first_token,
        hidden,
        sampling: SamplingParams { max_new_tokens, temperature, top_k, ignore_eos, seed },
        prng_state,
        n_layers,
        n_heads,
        d_head,
        blocks: KvSeqExport { block_size, len: len as u64, full_hashes },
        kv,
    };
    h.check()?;
    Ok(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;

    #[test]
    fn roundtrip() {
        let item = StageItem::new(42)
            .with("a", HostTensor::f32(vec![2], vec![1.5, -2.5]))
            .with("b", HostTensor::i32(vec![1, 3], vec![7, 8, 9]))
            .finished();
        let got = decode(&encode(&item)).unwrap();
        assert_eq!(got.req_id, 42);
        assert!(got.finished);
        assert_eq!(got.tensors, item.tensors);
    }

    #[test]
    fn rejects_corruption() {
        let item = StageItem::new(1).with("a", HostTensor::f32(vec![4], vec![0.0; 4]));
        let mut bytes = encode(&item);
        bytes[0] ^= 0xFF; // magic
        assert!(decode(&bytes).is_err());
        let bytes2 = encode(&item);
        assert!(decode(&bytes2[..bytes2.len() - 2]).is_err());
    }

    fn kv_sample(rng: &mut crate::util::Prng) -> KvHandoff {
        let n_layers = rng.range(1, 3);
        let n_heads = rng.range(1, 3);
        let d_head = rng.range(1, 4);
        let len = rng.range(1, 9);
        let block_size = rng.range(1, 4) as u32;
        let n_full = len / block_size as usize;
        KvHandoff {
            req_id: rng.next_u64(),
            len,
            first_token: rng.next_u64() as u32,
            hidden: (0..rng.range(0, 8)).map(|_| rng.f32() - 0.5).collect(),
            sampling: SamplingParams {
                max_new_tokens: rng.range(1, 64),
                temperature: rng.f32(),
                top_k: rng.range(0, 16),
                ignore_eos: rng.bool(0.5),
                seed: rng.next_u64(),
            },
            prng_state: rng.next_u64(),
            n_layers,
            n_heads,
            d_head,
            blocks: KvSeqExport {
                block_size,
                len: len as u64,
                full_hashes: (0..n_full)
                    .map(|_| if rng.bool(0.7) { Some(rng.next_u64()) } else { None })
                    .collect(),
            },
            kv: (0..n_layers * 2 * n_heads * len * d_head).map(|_| rng.f32() - 0.5).collect(),
        }
    }

    #[test]
    fn prop_kv_frame_roundtrips() {
        quick("kv_wire_roundtrip", |rng| {
            let h = kv_sample(rng);
            let got = decode_kv(&encode_kv(&h)).unwrap();
            assert_eq!(got, h);
        });
    }

    #[test]
    fn kv_frame_rejects_every_truncation() {
        let mut rng = crate::util::Prng::new(7);
        let bytes = encode_kv(&kv_sample(&mut rng));
        // Every proper prefix must decode to an error, never a panic.
        for cut in 0..bytes.len() {
            assert!(decode_kv(&bytes[..cut]).is_err(), "prefix of {cut} bytes decoded");
        }
        assert!(decode_kv(&bytes).is_ok());
    }

    #[test]
    fn prop_kv_frame_rejects_bit_flips() {
        // The trailing checksum makes ANY single-byte corruption — header,
        // hashes, or f32 payload — a decode error.
        quick("kv_wire_corruption", |rng| {
            let h = kv_sample(rng);
            let mut bytes = encode_kv(&h);
            let i = rng.range(0, bytes.len() - 1);
            let flip = (rng.below(255) + 1) as u8;
            bytes[i] ^= flip;
            assert!(decode_kv(&bytes).is_err(), "flip at byte {i} slipped through");
        });
    }

    #[test]
    fn kv_frame_rejects_wrong_magic_and_version() {
        let mut rng = crate::util::Prng::new(11);
        let h = kv_sample(&mut rng);
        // A StageItem frame is not a kv frame (different magic), even with
        // a "valid checksum" appended by an attacker-less accident.
        let item = StageItem::new(1).with("a", HostTensor::f32(vec![2], vec![0.0; 2]));
        let mut fake = encode(&item);
        let sum = super::fnv1a(&fake);
        fake.extend_from_slice(&sum.to_le_bytes());
        assert!(decode_kv(&fake).is_err());
        // Unsupported version (checksum recomputed so only the version
        // check can reject it).
        let mut bytes = encode_kv(&h);
        bytes[4] = 99;
        let body_len = bytes.len() - 8;
        let sum = super::fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert!(decode_kv(&bytes).is_err());
    }

    #[test]
    fn item_frame_rejects_every_truncation() {
        let item = StageItem::new(3)
            .with("tokens", HostTensor::i32(vec![3], vec![1, 2, 3]))
            .with("hiddens", HostTensor::f32(vec![2, 2], vec![0.5; 4]))
            .finished();
        let bytes = encode(&item);
        for cut in 0..bytes.len() {
            assert!(decode(&bytes[..cut]).is_err(), "prefix of {cut} bytes decoded");
        }
        assert!(decode(&bytes).is_ok());
    }

    #[test]
    fn prop_roundtrip_random_items() {
        quick("wire_roundtrip", |rng| {
            let mut item = StageItem::new(rng.next_u64());
            item.finished = rng.bool(0.5);
            for ti in 0..rng.range(0, 4) {
                let n = rng.range(0, 16);
                if rng.bool(0.5) {
                    let v: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
                    item.tensors
                        .insert(format!("t{ti}"), HostTensor::f32(vec![n], v));
                } else {
                    let v: Vec<i32> = (0..n).map(|_| rng.next_u64() as i32).collect();
                    item.tensors
                        .insert(format!("t{ti}"), HostTensor::i32(vec![n], v));
                }
            }
            let got = decode(&encode(&item)).unwrap();
            assert_eq!(got.req_id, item.req_id);
            assert_eq!(got.tensors, item.tensors);
        });
    }
}
