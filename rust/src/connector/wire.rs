//! Wire format for [`StageItem`]s crossing shm/TCP connectors.
//!
//! Layout (little-endian):
//! `magic u32 | req_id u64 | flags u8 | n_tensors u32 |`
//! per tensor: `name_len u32 | name bytes | blob_len u64 | tensor blob`
//! (tensor blob as produced by [`HostTensor::to_bytes`]).

use anyhow::{bail, Result};

use crate::engine::StageItem;
use crate::runtime::HostTensor;

const MAGIC: u32 = 0x4F4D4E49; // "OMNI"

pub fn encode(item: &StageItem) -> Vec<u8> {
    let mut out = Vec::with_capacity(64 + item.payload_bytes());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&item.req_id.to_le_bytes());
    out.push(item.finished as u8);
    out.extend_from_slice(&(item.tensors.len() as u32).to_le_bytes());
    for (name, t) in &item.tensors {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        let blob = t.to_bytes();
        out.extend_from_slice(&(blob.len() as u64).to_le_bytes());
        out.extend_from_slice(&blob);
    }
    out
}

pub fn decode(bytes: &[u8]) -> Result<StageItem> {
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > bytes.len() {
            bail!("wire: truncated at {} (+{n} > {})", *pos, bytes.len());
        }
        let s = &bytes[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let magic = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
    if magic != MAGIC {
        bail!("wire: bad magic {magic:#x}");
    }
    let req_id = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
    let finished = take(&mut pos, 1)?[0] != 0;
    let n = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
    let mut item = StageItem::new(req_id);
    item.finished = finished;
    for _ in 0..n {
        let name_len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
            .map_err(|_| anyhow::anyhow!("wire: non-utf8 tensor name"))?;
        let blob_len = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap()) as usize;
        let t = HostTensor::from_bytes(take(&mut pos, blob_len)?)?;
        item.tensors.insert(name, t);
    }
    Ok(item)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;

    #[test]
    fn roundtrip() {
        let item = StageItem::new(42)
            .with("a", HostTensor::f32(vec![2], vec![1.5, -2.5]))
            .with("b", HostTensor::i32(vec![1, 3], vec![7, 8, 9]))
            .finished();
        let got = decode(&encode(&item)).unwrap();
        assert_eq!(got.req_id, 42);
        assert!(got.finished);
        assert_eq!(got.tensors, item.tensors);
    }

    #[test]
    fn rejects_corruption() {
        let item = StageItem::new(1).with("a", HostTensor::f32(vec![4], vec![0.0; 4]));
        let mut bytes = encode(&item);
        bytes[0] ^= 0xFF; // magic
        assert!(decode(&bytes).is_err());
        let bytes2 = encode(&item);
        assert!(decode(&bytes2[..bytes2.len() - 2]).is_err());
    }

    #[test]
    fn prop_roundtrip_random_items() {
        quick("wire_roundtrip", |rng| {
            let mut item = StageItem::new(rng.next_u64());
            item.finished = rng.bool(0.5);
            for ti in 0..rng.range(0, 4) {
                let n = rng.range(0, 16);
                if rng.bool(0.5) {
                    let v: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
                    item.tensors
                        .insert(format!("t{ti}"), HostTensor::f32(vec![n], v));
                } else {
                    let v: Vec<i32> = (0..n).map(|_| rng.next_u64() as i32).collect();
                    item.tensors
                        .insert(format!("t{ti}"), HostTensor::i32(vec![n], v));
                }
            }
            let got = decode(&encode(&item)).unwrap();
            assert_eq!(got.req_id, item.req_id);
            assert_eq!(got.tensors, item.tensors);
        });
    }
}
