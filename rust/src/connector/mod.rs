//! Unified inter-stage connector (paper §3.4, Table 1).
//!
//! Decouples transport from model logic: every edge of the stage graph
//! moves [`StageItem`]s through a connector chosen per edge:
//!
//! * [`ConnectorKind::Inline`] — in-process queue; payload travels with
//!   the control message (single-node, small payloads).
//! * [`ConnectorKind::Shm`] — POSIX shared memory for the payload,
//!   inline queue for metadata (single-node, large payloads).
//! * [`ConnectorKind::Tcp`] — Mooncake-like put/get store over TCP with
//!   only lightweight metadata on the control plane (multi-node).
//!
//! All three expose the same `send`/`recv` surface, so deployments can
//! switch transports per edge without touching stage code — the paper's
//! "per-edge connector setting".

pub mod shm;
pub mod tcp;
pub mod wire;

use std::sync::mpsc;

use anyhow::Result;

use crate::config::ConnectorKind;
use crate::engine::StageItem;

/// Control-plane message: either the payload itself (inline) or a
/// reference to where the payload was put.
enum Ctrl {
    Inline(Box<StageItem>),
    Shm { name: String, len: usize },
    Tcp { key: String },
}

/// Sending half (owned by the producer stage thread).
pub struct ConnectorTx {
    kind: ConnectorKind,
    ctrl: mpsc::Sender<Ctrl>,
    tcp: Option<tcp::StoreClient>,
    seq: u64,
    label: String,
    /// Bytes moved through the payload plane (metrics / Table 1).
    pub bytes_sent: u64,
}

/// Receiving half (owned by the consumer stage thread).
pub struct ConnectorRx {
    ctrl: mpsc::Receiver<Ctrl>,
    tcp: Option<tcp::StoreClient>,
}

/// Create a connected pair.  For `Tcp`, `store_addr` must point at a
/// running [`tcp::MooncakeStore`].
pub fn pair(kind: ConnectorKind, label: &str, store_addr: Option<&str>) -> Result<(ConnectorTx, ConnectorRx)> {
    let (tx, rx) = mpsc::channel();
    let (tcp_tx, tcp_rx) = match kind {
        ConnectorKind::Tcp => {
            let addr = store_addr
                .ok_or_else(|| anyhow::anyhow!("tcp connector needs a store address"))?;
            (Some(tcp::StoreClient::connect(addr)?), Some(tcp::StoreClient::connect(addr)?))
        }
        _ => (None, None),
    };
    Ok((
        ConnectorTx { kind, ctrl: tx, tcp: tcp_tx, seq: 0, label: label.to_string(), bytes_sent: 0 },
        ConnectorRx { ctrl: rx, tcp: tcp_rx },
    ))
}

impl ConnectorTx {
    pub fn send(&mut self, item: StageItem) -> Result<()> {
        match self.kind {
            ConnectorKind::Inline => {
                self.bytes_sent += item.payload_bytes() as u64;
                self.ctrl
                    .send(Ctrl::Inline(Box::new(item)))
                    .map_err(|_| anyhow::anyhow!("connector closed"))?;
            }
            ConnectorKind::Shm => {
                let bytes = wire::encode(&item);
                self.bytes_sent += bytes.len() as u64;
                let name = format!("/omni_{}_{}_{}", std::process::id(), self.label, self.seq);
                self.seq += 1;
                shm::write_segment(&name, &bytes)?;
                self.ctrl
                    .send(Ctrl::Shm { name, len: bytes.len() })
                    .map_err(|_| anyhow::anyhow!("connector closed"))?;
            }
            ConnectorKind::Tcp => {
                let bytes = wire::encode(&item);
                self.bytes_sent += bytes.len() as u64;
                let key = format!("{}:{}", self.label, self.seq);
                self.seq += 1;
                self.tcp.as_mut().unwrap().put(&key, &bytes)?;
                self.ctrl
                    .send(Ctrl::Tcp { key })
                    .map_err(|_| anyhow::anyhow!("connector closed"))?;
            }
        }
        Ok(())
    }
}

impl ConnectorRx {
    /// Non-blocking receive.
    pub fn try_recv(&mut self) -> Result<Option<StageItem>> {
        match self.ctrl.try_recv() {
            Ok(ctrl) => Ok(Some(self.resolve(ctrl)?)),
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => Ok(None),
        }
    }

    /// Blocking receive; `None` when the producer hung up.
    pub fn recv(&mut self) -> Result<Option<StageItem>> {
        match self.ctrl.recv() {
            Ok(ctrl) => Ok(Some(self.resolve(ctrl)?)),
            Err(_) => Ok(None),
        }
    }

    fn resolve(&mut self, ctrl: Ctrl) -> Result<StageItem> {
        match ctrl {
            Ctrl::Inline(item) => Ok(*item),
            Ctrl::Shm { name, len } => {
                let bytes = shm::read_segment(&name, len)?;
                shm::unlink(&name);
                wire::decode(&bytes)
            }
            Ctrl::Tcp { key } => {
                let bytes = self.tcp.as_mut().unwrap().get(&key)?;
                wire::decode(&bytes)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    fn item(req: u64) -> StageItem {
        StageItem::new(req)
            .with("tokens", HostTensor::i32(vec![3], vec![1, 2, 3]))
            .with("hiddens", HostTensor::f32(vec![2, 4], vec![0.5; 8]))
    }

    #[test]
    fn inline_roundtrip() {
        let (mut tx, mut rx) = pair(ConnectorKind::Inline, "t", None).unwrap();
        tx.send(item(7)).unwrap();
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.req_id, 7);
        assert_eq!(got.tensor("tokens").unwrap().as_i32().unwrap(), &[1, 2, 3]);
        assert!(rx.try_recv().unwrap().is_none());
    }

    #[test]
    fn shm_roundtrip() {
        let (mut tx, mut rx) = pair(ConnectorKind::Shm, "tshm", None).unwrap();
        tx.send(item(9).finished()).unwrap();
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.req_id, 9);
        assert!(got.finished);
        assert_eq!(got.tensor("hiddens").unwrap().shape, vec![2, 4]);
    }

    #[test]
    fn tcp_roundtrip() {
        let store = tcp::MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let addr = store.addr().to_string();
        let (mut tx, mut rx) = pair(ConnectorKind::Tcp, "ttcp", Some(&addr)).unwrap();
        for i in 0..5 {
            tx.send(item(i)).unwrap();
        }
        for i in 0..5 {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(got.req_id, i);
        }
    }

    #[test]
    fn cross_thread_inline() {
        let (mut tx, mut rx) = pair(ConnectorKind::Inline, "x", None).unwrap();
        let h = std::thread::spawn(move || {
            for i in 0..100 {
                tx.send(item(i)).unwrap();
            }
        });
        let mut got = 0;
        while got < 100 {
            if let Some(it) = rx.recv().unwrap() {
                assert_eq!(it.req_id, got);
                got += 1;
            }
        }
        h.join().unwrap();
    }
}
