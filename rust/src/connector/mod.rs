//! Unified inter-stage connector (paper §3.4, Table 1).
//!
//! Decouples transport from model logic: every edge of the stage graph
//! moves [`StageItem`]s through a connector chosen per edge:
//!
//! * [`ConnectorKind::Inline`] — in-process queue; payload travels with
//!   the control message (single-node, small payloads).
//! * [`ConnectorKind::Shm`] — POSIX shared memory for the payload,
//!   inline queue for metadata (single-node, large payloads).
//! * [`ConnectorKind::Tcp`] — Mooncake-like put/get store over TCP with
//!   only lightweight metadata on the control plane (multi-node).
//!
//! All three expose the same `send`/`recv` surface, so deployments can
//! switch transports per edge without touching stage code — the paper's
//! "per-edge connector setting".

pub mod router;
pub mod shm;
pub mod tcp;
pub mod wire;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::config::{ConnectorKind, TransportConfig};
use crate::engine::StageItem;
use crate::util::stats::Samples;

/// Shared per-edge transfer counters (ISSUE 8): bytes and frames moved
/// through the payload plane, plus send→resolve latency samples.  One
/// instance is shared by every connector pair fanning out a logical
/// edge, so the numbers describe the edge, not a single replica link.
/// Without these, placement decisions fly blind.
#[derive(Default)]
pub struct EdgeTransferStats {
    bytes: AtomicU64,
    frames: AtomicU64,
    lat: Mutex<Samples>,
}

/// Point-in-time copy of an edge's transfer counters, for
/// `StageSummary`/`RunReport` rollups and the `stats` op.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EdgeTransferSnapshot {
    /// Edge label ("thinker->talker"), filled in by the roll-up layer.
    pub label: String,
    pub bytes: u64,
    pub frames: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

impl EdgeTransferStats {
    pub(crate) fn record_sent(&self, bytes: u64) {
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.frames.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn record_latency(&self, secs: f64) {
        self.lat.lock().unwrap().push(secs * 1e3);
    }

    /// Snapshot with an empty label (the caller knows which edge it is).
    pub fn snapshot(&self) -> EdgeTransferSnapshot {
        // `percentile` returns 0.0 on an empty sample set.
        let mut lat = self.lat.lock().unwrap();
        EdgeTransferSnapshot {
            label: String::new(),
            bytes: self.bytes.load(Ordering::Relaxed),
            frames: self.frames.load(Ordering::Relaxed),
            p50_ms: lat.percentile(50.0),
            p95_ms: lat.percentile(95.0),
        }
    }
}

/// Name of a written shm segment.  Unlinks on drop, so the segment can
/// never leak no matter where its control message dies: resolved by the
/// consumer (read, then dropped), stuck in the queue when the channel is
/// torn down, or bounced back inside a failed send's `SendError`.
struct ShmSegment(String);

impl Drop for ShmSegment {
    fn drop(&mut self) {
        shm::unlink(&self.0);
    }
}

/// Key of a value parked in the Mooncake store.  Unless the consumer
/// resolves it (the normal get-and-remove path), dropping the guard
/// issues a non-blocking `DEL` over a fresh connection — so a key
/// destroyed anywhere (failed send's `SendError`, queued at channel
/// teardown, receiver-drop drain) reclaims its stored value.
struct TcpValue {
    key: String,
    store_addr: String,
    resolved: bool,
}

impl Drop for TcpValue {
    fn drop(&mut self) {
        if !self.resolved {
            if let Ok(mut c) = tcp::StoreClient::connect(&self.store_addr) {
                let _ = c.del(&self.key);
            }
        }
    }
}

/// Control-plane message: either the payload itself (inline) or a
/// reference to where the payload was put.
enum CtrlBody {
    Inline(Box<StageItem>),
    Shm { seg: ShmSegment, len: usize },
    Tcp { val: TcpValue },
}

/// Control message plus its send timestamp (per-edge transfer latency).
struct Ctrl {
    sent_at: Instant,
    body: CtrlBody,
}

/// Sending half (owned by the producer stage thread).
pub struct ConnectorTx {
    kind: ConnectorKind,
    ctrl: mpsc::Sender<Ctrl>,
    tcp: Option<tcp::StoreClient>,
    /// Store address for [`TcpValue`] reclaim guards (`Tcp` only).
    store_addr: Option<String>,
    seq: u64,
    label: String,
    /// Bytes moved through the payload plane (metrics / Table 1).
    pub bytes_sent: u64,
    /// Shared per-edge counters; `None` when nobody is watching.
    stats: Option<Arc<EdgeTransferStats>>,
}

/// Receiving half (owned by the consumer stage thread).
pub struct ConnectorRx {
    ctrl: mpsc::Receiver<Ctrl>,
    tcp: Option<tcp::StoreClient>,
    stats: Option<Arc<EdgeTransferStats>>,
}

/// Outcome of a non-blocking receive.  `Closed` (producer hung up and the
/// channel is drained) is distinct from `Empty` (nothing *yet*) so pollers
/// can stop spinning on dead edges.
#[derive(Debug)]
pub enum TryRecv {
    Item(StageItem),
    Empty,
    Closed,
}

/// Create a connected pair.  For `Tcp`, `store_addr` must point at a
/// running [`tcp::MooncakeStore`].
pub fn pair(kind: ConnectorKind, label: &str, store_addr: Option<&str>) -> Result<(ConnectorTx, ConnectorRx)> {
    pair_with(kind, label, store_addr, &TransportConfig::default(), None)
}

/// [`pair`] with explicit transport liveness knobs and optional shared
/// per-edge transfer counters (ISSUE 8).
pub fn pair_with(
    kind: ConnectorKind,
    label: &str,
    store_addr: Option<&str>,
    transport: &TransportConfig,
    stats: Option<Arc<EdgeTransferStats>>,
) -> Result<(ConnectorTx, ConnectorRx)> {
    let (tx, rx) = mpsc::channel();
    let (tcp_tx, tcp_rx, addr) = match kind {
        ConnectorKind::Tcp => {
            let addr = store_addr
                .ok_or_else(|| anyhow::anyhow!("tcp connector needs a store address"))?;
            (
                Some(tcp::StoreClient::connect_with(addr, transport, label)?),
                Some(tcp::StoreClient::connect_with(addr, transport, label)?),
                Some(addr.to_string()),
            )
        }
        _ => (None, None, None),
    };
    Ok((
        ConnectorTx {
            kind,
            ctrl: tx,
            tcp: tcp_tx,
            store_addr: addr,
            seq: 0,
            label: label.to_string(),
            bytes_sent: 0,
            stats: stats.clone(),
        },
        ConnectorRx { ctrl: rx, tcp: tcp_rx, stats },
    ))
}

impl ConnectorTx {
    pub fn send(&mut self, item: StageItem) -> Result<()> {
        let frame_bytes;
        let body = match self.kind {
            ConnectorKind::Inline => {
                frame_bytes = item.payload_bytes() as u64;
                CtrlBody::Inline(Box::new(item))
            }
            ConnectorKind::Shm => {
                let bytes = wire::encode(&item);
                frame_bytes = bytes.len() as u64;
                let name = format!("/omni_{}_{}_{}", std::process::id(), self.label, self.seq);
                self.seq += 1;
                shm::write_segment(&name, &bytes)?;
                // On failure the `SendError` carries the message back and
                // drops it here, which unlinks the orphaned segment.
                CtrlBody::Shm { seg: ShmSegment(name), len: bytes.len() }
            }
            ConnectorKind::Tcp => {
                let bytes = wire::encode(&item);
                frame_bytes = bytes.len() as u64;
                let key = format!("{}:{}", self.label, self.seq);
                self.seq += 1;
                self.tcp.as_mut().unwrap().put(&key, &bytes)?;
                // On failure the `SendError` carries the message back and
                // drops it here; the guard DELs the parked value.
                CtrlBody::Tcp {
                    val: TcpValue {
                        key,
                        store_addr: self.store_addr.clone().expect("set for Tcp in pair()"),
                        resolved: false,
                    },
                }
            }
        };
        self.bytes_sent += frame_bytes;
        self.ctrl
            .send(Ctrl { sent_at: Instant::now(), body })
            .map_err(|_| anyhow::anyhow!("connector closed"))?;
        if let Some(stats) = &self.stats {
            stats.record_sent(frame_bytes);
        }
        Ok(())
    }
}

impl ConnectorRx {
    /// Non-blocking receive.  [`TryRecv::Closed`] means the producer hung
    /// up AND the channel is drained — callers must not keep polling a
    /// closed edge expecting more data.
    pub fn try_recv(&mut self) -> Result<TryRecv> {
        match self.ctrl.try_recv() {
            Ok(ctrl) => Ok(TryRecv::Item(self.resolve(ctrl)?)),
            Err(mpsc::TryRecvError::Empty) => Ok(TryRecv::Empty),
            Err(mpsc::TryRecvError::Disconnected) => Ok(TryRecv::Closed),
        }
    }

    /// Blocking receive; `None` when the producer hung up.
    pub fn recv(&mut self) -> Result<Option<StageItem>> {
        match self.ctrl.recv() {
            Ok(ctrl) => Ok(Some(self.resolve(ctrl)?)),
            Err(_) => Ok(None),
        }
    }

    fn resolve(&mut self, ctrl: Ctrl) -> Result<StageItem> {
        let item = match ctrl.body {
            CtrlBody::Inline(item) => *item,
            CtrlBody::Shm { seg, len } => {
                // `seg` drops (and unlinks) at the end of this arm —
                // including on a read or decode error.
                let bytes = shm::read_segment(&seg.0, len)?;
                wire::decode(&bytes)?
            }
            CtrlBody::Tcp { mut val } => {
                let bytes = self.tcp.as_mut().unwrap().get(&val.key)?;
                // The blocking get removed the value; disarm the guard so
                // its drop skips the redundant DEL round trip.  (On a get
                // error the guard stays armed and DELs best-effort.)
                val.resolved = true;
                wire::decode(&bytes)?
            }
        };
        if let Some(stats) = &self.stats {
            stats.record_latency(ctrl.sent_at.elapsed().as_secs_f64());
        }
        Ok(item)
    }
}

impl Drop for ConnectorRx {
    /// Reclaim payloads the producer parked but nobody resolved
    /// (abandoned run, early consumer exit): drain the control queue so
    /// every pending message's guard fires *now* — [`ShmSegment`]
    /// unlinks its segment, [`TcpValue`] DELs its stored value.  TCP
    /// reclaims reuse this receiver's store connection (one DEL round
    /// trip each, no per-value handshake); the guard's fresh-connection
    /// fallback stays armed only if that client is somehow gone.  A
    /// message that slips in after this drain is destroyed by the
    /// channel itself, and its guard fires then — nothing leaks either
    /// way; the drain only makes reclamation prompt.
    fn drop(&mut self) {
        while let Ok(ctrl) = self.ctrl.try_recv() {
            if let CtrlBody::Tcp { mut val } = ctrl.body {
                if let Some(tcp) = self.tcp.as_mut() {
                    if tcp.del(&val.key).is_ok() {
                        val.resolved = true; // reclaimed; disarm the guard
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    fn item(req: u64) -> StageItem {
        StageItem::new(req)
            .with("tokens", HostTensor::i32(vec![3], vec![1, 2, 3]))
            .with("hiddens", HostTensor::f32(vec![2, 4], vec![0.5; 8]))
    }

    #[test]
    fn inline_roundtrip() {
        let (mut tx, mut rx) = pair(ConnectorKind::Inline, "t", None).unwrap();
        tx.send(item(7)).unwrap();
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.req_id, 7);
        assert_eq!(got.tensor("tokens").unwrap().as_i32().unwrap(), &[1, 2, 3]);
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Empty));
    }

    #[test]
    fn try_recv_distinguishes_empty_from_hangup() {
        let (mut tx, mut rx) = pair(ConnectorKind::Inline, "tri", None).unwrap();
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Empty), "live producer, no data");
        tx.send(item(1)).unwrap();
        drop(tx);
        // Queued items still drain after the hangup...
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Item(_)));
        // ...and only THEN does the edge report closed.
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Closed));
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Closed));
    }

    #[test]
    fn dropped_rx_reclaims_undelivered_shm_segments() {
        let label = format!("leak{}", std::process::id());
        let (mut tx, rx) = pair(ConnectorKind::Shm, &label, None).unwrap();
        tx.send(item(1)).unwrap();
        tx.send(item(2)).unwrap();
        // The segments exist while undelivered...
        let seg0 = format!("/omni_{}_{}_0", std::process::id(), label);
        let seg1 = format!("/omni_{}_{}_1", std::process::id(), label);
        assert!(shm::read_segment(&seg0, 1).is_ok());
        assert!(shm::read_segment(&seg1, 1).is_ok());
        // ...and are unlinked when the consumer drops without resolving.
        drop(rx);
        assert!(shm::read_segment(&seg0, 1).is_err(), "segment 0 leaked");
        assert!(shm::read_segment(&seg1, 1).is_err(), "segment 1 leaked");
    }

    #[test]
    fn failed_send_does_not_leak_shm_segment() {
        let label = format!("sendfail{}", std::process::id());
        let (mut tx, rx) = pair(ConnectorKind::Shm, &label, None).unwrap();
        drop(rx);
        assert!(tx.send(item(1)).is_err());
        let seg = format!("/omni_{}_{}_0", std::process::id(), label);
        assert!(shm::read_segment(&seg, 1).is_err(), "abandoned send leaked its segment");
    }

    #[test]
    fn dropped_rx_reclaims_undelivered_tcp_values() {
        let store = tcp::MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let addr = store.addr().to_string();
        let (mut tx, rx) = pair(ConnectorKind::Tcp, "tleak", Some(&addr)).unwrap();
        tx.send(item(1)).unwrap();
        tx.send(item(2)).unwrap();
        assert_eq!(store.len(), 2);
        drop(rx);
        assert_eq!(store.len(), 0, "undelivered TCP values leaked in the store");
    }

    #[test]
    fn failed_tcp_send_does_not_leak_store_value() {
        let store = tcp::MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let addr = store.addr().to_string();
        let (mut tx, rx) = pair(ConnectorKind::Tcp, "tsendfail", Some(&addr)).unwrap();
        drop(rx);
        assert!(tx.send(item(1)).is_err());
        assert_eq!(store.len(), 0, "abandoned TCP send leaked its value");
    }

    #[test]
    fn shm_roundtrip() {
        let (mut tx, mut rx) = pair(ConnectorKind::Shm, "tshm", None).unwrap();
        tx.send(item(9).finished()).unwrap();
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.req_id, 9);
        assert!(got.finished);
        assert_eq!(got.tensor("hiddens").unwrap().shape, vec![2, 4]);
    }

    #[test]
    fn tcp_roundtrip() {
        let store = tcp::MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let addr = store.addr().to_string();
        let (mut tx, mut rx) = pair(ConnectorKind::Tcp, "ttcp", Some(&addr)).unwrap();
        for i in 0..5 {
            tx.send(item(i)).unwrap();
        }
        for i in 0..5 {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(got.req_id, i);
        }
    }

    #[test]
    fn edge_stats_count_bytes_frames_and_latency() {
        let stats = Arc::new(EdgeTransferStats::default());
        let (mut tx, mut rx) = pair_with(
            ConnectorKind::Inline,
            "stat",
            None,
            &TransportConfig::default(),
            Some(stats.clone()),
        )
        .unwrap();
        for i in 0..4 {
            tx.send(item(i)).unwrap();
        }
        for _ in 0..4 {
            rx.recv().unwrap().unwrap();
        }
        let snap = stats.snapshot();
        assert_eq!(snap.frames, 4);
        assert_eq!(snap.bytes, tx.bytes_sent);
        assert!(snap.bytes > 0);
        assert!(snap.p50_ms >= 0.0 && snap.p95_ms >= snap.p50_ms);
    }

    #[test]
    fn cross_thread_inline() {
        let (mut tx, mut rx) = pair(ConnectorKind::Inline, "x", None).unwrap();
        let h = std::thread::spawn(move || {
            for i in 0..100 {
                tx.send(item(i)).unwrap();
            }
        });
        let mut got = 0;
        while got < 100 {
            if let Some(it) = rx.recv().unwrap() {
                assert_eq!(it.req_id, got);
                got += 1;
            }
        }
        h.join().unwrap();
    }
}
