//! Unified inter-stage connector (paper §3.4, Table 1).
//!
//! Decouples transport from model logic: every edge of the stage graph
//! moves [`StageItem`]s through a connector chosen per edge:
//!
//! * [`ConnectorKind::Inline`] — in-process queue; payload travels with
//!   the control message (single-node, small payloads).
//! * [`ConnectorKind::Shm`] — POSIX shared memory for the payload,
//!   inline queue for metadata (single-node, large payloads).
//! * [`ConnectorKind::Tcp`] — Mooncake-like put/get store over TCP with
//!   only lightweight metadata on the control plane (multi-node).
//!
//! All three expose the same `send`/`recv` surface, so deployments can
//! switch transports per edge without touching stage code — the paper's
//! "per-edge connector setting".

pub mod router;
pub mod shm;
pub mod tcp;
pub mod wire;

use std::sync::mpsc;

use anyhow::Result;

use crate::config::ConnectorKind;
use crate::engine::StageItem;

/// Name of a written shm segment.  Unlinks on drop, so the segment can
/// never leak no matter where its control message dies: resolved by the
/// consumer (read, then dropped), stuck in the queue when the channel is
/// torn down, or bounced back inside a failed send's `SendError`.
struct ShmSegment(String);

impl Drop for ShmSegment {
    fn drop(&mut self) {
        shm::unlink(&self.0);
    }
}

/// Key of a value parked in the Mooncake store.  Unless the consumer
/// resolves it (the normal get-and-remove path), dropping the guard
/// issues a non-blocking `DEL` over a fresh connection — so a key
/// destroyed anywhere (failed send's `SendError`, queued at channel
/// teardown, receiver-drop drain) reclaims its stored value.
struct TcpValue {
    key: String,
    store_addr: String,
    resolved: bool,
}

impl Drop for TcpValue {
    fn drop(&mut self) {
        if !self.resolved {
            if let Ok(mut c) = tcp::StoreClient::connect(&self.store_addr) {
                let _ = c.del(&self.key);
            }
        }
    }
}

/// Control-plane message: either the payload itself (inline) or a
/// reference to where the payload was put.
enum Ctrl {
    Inline(Box<StageItem>),
    Shm { seg: ShmSegment, len: usize },
    Tcp { val: TcpValue },
}

/// Sending half (owned by the producer stage thread).
pub struct ConnectorTx {
    kind: ConnectorKind,
    ctrl: mpsc::Sender<Ctrl>,
    tcp: Option<tcp::StoreClient>,
    /// Store address for [`TcpValue`] reclaim guards (`Tcp` only).
    store_addr: Option<String>,
    seq: u64,
    label: String,
    /// Bytes moved through the payload plane (metrics / Table 1).
    pub bytes_sent: u64,
}

/// Receiving half (owned by the consumer stage thread).
pub struct ConnectorRx {
    ctrl: mpsc::Receiver<Ctrl>,
    tcp: Option<tcp::StoreClient>,
}

/// Outcome of a non-blocking receive.  `Closed` (producer hung up and the
/// channel is drained) is distinct from `Empty` (nothing *yet*) so pollers
/// can stop spinning on dead edges.
#[derive(Debug)]
pub enum TryRecv {
    Item(StageItem),
    Empty,
    Closed,
}

/// Create a connected pair.  For `Tcp`, `store_addr` must point at a
/// running [`tcp::MooncakeStore`].
pub fn pair(kind: ConnectorKind, label: &str, store_addr: Option<&str>) -> Result<(ConnectorTx, ConnectorRx)> {
    let (tx, rx) = mpsc::channel();
    let (tcp_tx, tcp_rx, addr) = match kind {
        ConnectorKind::Tcp => {
            let addr = store_addr
                .ok_or_else(|| anyhow::anyhow!("tcp connector needs a store address"))?;
            (
                Some(tcp::StoreClient::connect(addr)?),
                Some(tcp::StoreClient::connect(addr)?),
                Some(addr.to_string()),
            )
        }
        _ => (None, None, None),
    };
    Ok((
        ConnectorTx {
            kind,
            ctrl: tx,
            tcp: tcp_tx,
            store_addr: addr,
            seq: 0,
            label: label.to_string(),
            bytes_sent: 0,
        },
        ConnectorRx { ctrl: rx, tcp: tcp_rx },
    ))
}

impl ConnectorTx {
    pub fn send(&mut self, item: StageItem) -> Result<()> {
        match self.kind {
            ConnectorKind::Inline => {
                self.bytes_sent += item.payload_bytes() as u64;
                self.ctrl
                    .send(Ctrl::Inline(Box::new(item)))
                    .map_err(|_| anyhow::anyhow!("connector closed"))?;
            }
            ConnectorKind::Shm => {
                let bytes = wire::encode(&item);
                self.bytes_sent += bytes.len() as u64;
                let name = format!("/omni_{}_{}_{}", std::process::id(), self.label, self.seq);
                self.seq += 1;
                shm::write_segment(&name, &bytes)?;
                // On failure the `SendError` carries the message back and
                // drops it here, which unlinks the orphaned segment.
                self.ctrl
                    .send(Ctrl::Shm { seg: ShmSegment(name), len: bytes.len() })
                    .map_err(|_| anyhow::anyhow!("connector closed"))?;
            }
            ConnectorKind::Tcp => {
                let bytes = wire::encode(&item);
                self.bytes_sent += bytes.len() as u64;
                let key = format!("{}:{}", self.label, self.seq);
                self.seq += 1;
                self.tcp.as_mut().unwrap().put(&key, &bytes)?;
                let val = TcpValue {
                    key,
                    store_addr: self.store_addr.clone().expect("set for Tcp in pair()"),
                    resolved: false,
                };
                // On failure the `SendError` carries the message back and
                // drops it here; the guard DELs the parked value.
                self.ctrl
                    .send(Ctrl::Tcp { val })
                    .map_err(|_| anyhow::anyhow!("connector closed"))?;
            }
        }
        Ok(())
    }
}

impl ConnectorRx {
    /// Non-blocking receive.  [`TryRecv::Closed`] means the producer hung
    /// up AND the channel is drained — callers must not keep polling a
    /// closed edge expecting more data.
    pub fn try_recv(&mut self) -> Result<TryRecv> {
        match self.ctrl.try_recv() {
            Ok(ctrl) => Ok(TryRecv::Item(self.resolve(ctrl)?)),
            Err(mpsc::TryRecvError::Empty) => Ok(TryRecv::Empty),
            Err(mpsc::TryRecvError::Disconnected) => Ok(TryRecv::Closed),
        }
    }

    /// Blocking receive; `None` when the producer hung up.
    pub fn recv(&mut self) -> Result<Option<StageItem>> {
        match self.ctrl.recv() {
            Ok(ctrl) => Ok(Some(self.resolve(ctrl)?)),
            Err(_) => Ok(None),
        }
    }

    fn resolve(&mut self, ctrl: Ctrl) -> Result<StageItem> {
        match ctrl {
            Ctrl::Inline(item) => Ok(*item),
            Ctrl::Shm { seg, len } => {
                // `seg` drops (and unlinks) at the end of this arm —
                // including on a read or decode error.
                let bytes = shm::read_segment(&seg.0, len)?;
                wire::decode(&bytes)
            }
            Ctrl::Tcp { mut val } => {
                let bytes = self.tcp.as_mut().unwrap().get(&val.key)?;
                // The blocking get removed the value; disarm the guard so
                // its drop skips the redundant DEL round trip.  (On a get
                // error the guard stays armed and DELs best-effort.)
                val.resolved = true;
                wire::decode(&bytes)
            }
        }
    }
}

impl Drop for ConnectorRx {
    /// Reclaim payloads the producer parked but nobody resolved
    /// (abandoned run, early consumer exit): drain the control queue so
    /// every pending message's guard fires *now* — [`ShmSegment`]
    /// unlinks its segment, [`TcpValue`] DELs its stored value.  TCP
    /// reclaims reuse this receiver's store connection (one DEL round
    /// trip each, no per-value handshake); the guard's fresh-connection
    /// fallback stays armed only if that client is somehow gone.  A
    /// message that slips in after this drain is destroyed by the
    /// channel itself, and its guard fires then — nothing leaks either
    /// way; the drain only makes reclamation prompt.
    fn drop(&mut self) {
        while let Ok(ctrl) = self.ctrl.try_recv() {
            if let Ctrl::Tcp { mut val } = ctrl {
                if let Some(tcp) = self.tcp.as_mut() {
                    if tcp.del(&val.key).is_ok() {
                        val.resolved = true; // reclaimed; disarm the guard
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    fn item(req: u64) -> StageItem {
        StageItem::new(req)
            .with("tokens", HostTensor::i32(vec![3], vec![1, 2, 3]))
            .with("hiddens", HostTensor::f32(vec![2, 4], vec![0.5; 8]))
    }

    #[test]
    fn inline_roundtrip() {
        let (mut tx, mut rx) = pair(ConnectorKind::Inline, "t", None).unwrap();
        tx.send(item(7)).unwrap();
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.req_id, 7);
        assert_eq!(got.tensor("tokens").unwrap().as_i32().unwrap(), &[1, 2, 3]);
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Empty));
    }

    #[test]
    fn try_recv_distinguishes_empty_from_hangup() {
        let (mut tx, mut rx) = pair(ConnectorKind::Inline, "tri", None).unwrap();
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Empty), "live producer, no data");
        tx.send(item(1)).unwrap();
        drop(tx);
        // Queued items still drain after the hangup...
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Item(_)));
        // ...and only THEN does the edge report closed.
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Closed));
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Closed));
    }

    #[test]
    fn dropped_rx_reclaims_undelivered_shm_segments() {
        let label = format!("leak{}", std::process::id());
        let (mut tx, rx) = pair(ConnectorKind::Shm, &label, None).unwrap();
        tx.send(item(1)).unwrap();
        tx.send(item(2)).unwrap();
        // The segments exist while undelivered...
        let seg0 = format!("/omni_{}_{}_0", std::process::id(), label);
        let seg1 = format!("/omni_{}_{}_1", std::process::id(), label);
        assert!(shm::read_segment(&seg0, 1).is_ok());
        assert!(shm::read_segment(&seg1, 1).is_ok());
        // ...and are unlinked when the consumer drops without resolving.
        drop(rx);
        assert!(shm::read_segment(&seg0, 1).is_err(), "segment 0 leaked");
        assert!(shm::read_segment(&seg1, 1).is_err(), "segment 1 leaked");
    }

    #[test]
    fn failed_send_does_not_leak_shm_segment() {
        let label = format!("sendfail{}", std::process::id());
        let (mut tx, rx) = pair(ConnectorKind::Shm, &label, None).unwrap();
        drop(rx);
        assert!(tx.send(item(1)).is_err());
        let seg = format!("/omni_{}_{}_0", std::process::id(), label);
        assert!(shm::read_segment(&seg, 1).is_err(), "abandoned send leaked its segment");
    }

    #[test]
    fn dropped_rx_reclaims_undelivered_tcp_values() {
        let store = tcp::MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let addr = store.addr().to_string();
        let (mut tx, rx) = pair(ConnectorKind::Tcp, "tleak", Some(&addr)).unwrap();
        tx.send(item(1)).unwrap();
        tx.send(item(2)).unwrap();
        assert_eq!(store.len(), 2);
        drop(rx);
        assert_eq!(store.len(), 0, "undelivered TCP values leaked in the store");
    }

    #[test]
    fn failed_tcp_send_does_not_leak_store_value() {
        let store = tcp::MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let addr = store.addr().to_string();
        let (mut tx, rx) = pair(ConnectorKind::Tcp, "tsendfail", Some(&addr)).unwrap();
        drop(rx);
        assert!(tx.send(item(1)).is_err());
        assert_eq!(store.len(), 0, "abandoned TCP send leaked its value");
    }

    #[test]
    fn shm_roundtrip() {
        let (mut tx, mut rx) = pair(ConnectorKind::Shm, "tshm", None).unwrap();
        tx.send(item(9).finished()).unwrap();
        let got = rx.recv().unwrap().unwrap();
        assert_eq!(got.req_id, 9);
        assert!(got.finished);
        assert_eq!(got.tensor("hiddens").unwrap().shape, vec![2, 4]);
    }

    #[test]
    fn tcp_roundtrip() {
        let store = tcp::MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let addr = store.addr().to_string();
        let (mut tx, mut rx) = pair(ConnectorKind::Tcp, "ttcp", Some(&addr)).unwrap();
        for i in 0..5 {
            tx.send(item(i)).unwrap();
        }
        for i in 0..5 {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(got.req_id, i);
        }
    }

    #[test]
    fn cross_thread_inline() {
        let (mut tx, mut rx) = pair(ConnectorKind::Inline, "x", None).unwrap();
        let h = std::thread::spawn(move || {
            for i in 0..100 {
                tx.send(item(i)).unwrap();
            }
        });
        let mut got = 0;
        while got < 100 {
            if let Some(it) = rx.recv().unwrap() {
                assert_eq!(it.req_id, got);
                got += 1;
            }
        }
        h.join().unwrap();
    }
}
