//! Routed connector layer: 1→N fan-out and N→1 fan-in over the
//! point-to-point connectors in [`super`] (paper §3.3 "flexible GPU
//! allocation").
//!
//! When a stage runs `replicas > 1` engine threads, every edge touching
//! it becomes a *routed* edge: each producer replica owns a [`RouterTx`]
//! that picks a consumer replica per item, and each consumer replica owns
//! a [`RouterRx`] that merges the channels arriving from every producer
//! replica.  An edge between an `m`-replica producer and an `n`-replica
//! consumer is therefore `m × n` underlying connectors, all sharing the
//! transport ([`ConnectorKind`]) configured for the edge.
//!
//! Routing policies ([`RoutingKind`]):
//!
//! * **round-robin** — per-item rotation; maximal spread, only correct
//!   when items are independent (single-item requests).
//! * **least-depth** — per-item pick of the replica with the smallest
//!   load signal: connector in-flight count plus the consumer's
//!   *published* admission-queue depth (the stage thread exports its
//!   [`crate::scheduler::StageScheduler`] queue length through
//!   [`RouterRx::publish_queue_depth`] — the `SchedStats` feedback loop).
//! * **affinity** — per-request stickiness via `req_id % replicas`:
//!   deterministic across producer replicas and across edges, so a
//!   request's streamed chunks, conditioning rows, and KV/sequence state
//!   all live on one replica.  Required for replicated AR consumers
//!   (validated at config load).
//!
//! With one consumer replica every policy degenerates to pass-through,
//! which keeps single-replica pipelines behaviour-identical to the
//! pre-router point-to-point design.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::config::{ConnectorKind, RoutingKind};
use crate::engine::StageItem;

use super::{pair, ConnectorRx, ConnectorTx, TryRecv};

/// Shared load signal for one consumer replica of one edge.
///
/// * `in_flight` — items sent into the replica's channels and not yet
///   received (maintained by the router itself).
/// * `queue_depth` — the consumer stage thread's pending admission-queue
///   length, published each loop iteration (scheduler feedback).
#[derive(Debug, Default)]
pub struct ReplicaLoad {
    in_flight: AtomicUsize,
    queue_depth: AtomicUsize,
}

impl ReplicaLoad {
    /// Combined depth the least-depth policy ranks replicas by.
    fn score(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed) + self.queue_depth.load(Ordering::Relaxed)
    }
}

enum RouteState {
    RoundRobin { next: usize },
    LeastDepth,
    Affinity,
}

/// Fan-out sender owned by one producer replica: one [`ConnectorTx`] per
/// consumer replica, with the routing policy choosing the target per
/// item.
pub struct RouterTx {
    targets: Vec<ConnectorTx>,
    loads: Vec<Arc<ReplicaLoad>>,
    state: RouteState,
}

impl RouterTx {
    /// Route `item` to one consumer replica.
    pub fn send(&mut self, item: StageItem) -> Result<()> {
        let n = self.targets.len();
        let i = match &mut self.state {
            RouteState::RoundRobin { next } => {
                let i = *next % n;
                *next = (*next + 1) % n;
                i
            }
            RouteState::LeastDepth => (0..n)
                .min_by_key(|&i| (self.loads[i].score(), i))
                .expect("router has at least one target"),
            RouteState::Affinity => (item.req_id % n as u64) as usize,
        };
        // Count before sending so a racing consumer can never observe a
        // receive without the matching increment (underflow).
        self.loads[i].in_flight.fetch_add(1, Ordering::Relaxed);
        if let Err(e) = self.targets[i].send(item) {
            let _ = self.loads[i].in_flight.fetch_update(
                Ordering::Relaxed,
                Ordering::Relaxed,
                |v| Some(v.saturating_sub(1)),
            );
            return Err(e);
        }
        Ok(())
    }

    /// Total bytes moved through this producer replica's payload planes.
    pub fn bytes_sent(&self) -> u64 {
        self.targets.iter().map(|t| t.bytes_sent).sum()
    }

    /// Number of consumer replicas this sender fans out to.
    pub fn fanout(&self) -> usize {
        self.targets.len()
    }
}

struct Source {
    rx: ConnectorRx,
    open: bool,
}

/// Fan-in receiver owned by one consumer replica: merges the channels
/// from every producer replica, polling them round-robin for fairness.
pub struct RouterRx {
    sources: Vec<Source>,
    load: Arc<ReplicaLoad>,
    next: usize,
}

impl RouterRx {
    /// Non-blocking receive across all producer replicas.
    /// [`TryRecv::Closed`] only once EVERY producer has hung up and all
    /// channels are drained.
    pub fn try_recv(&mut self) -> Result<TryRecv> {
        let n = self.sources.len();
        let mut any_open = false;
        for k in 0..n {
            let i = (self.next + k) % n;
            if !self.sources[i].open {
                continue;
            }
            match self.sources[i].rx.try_recv()? {
                TryRecv::Item(item) => {
                    self.next = (i + 1) % n;
                    let _ = self.load.in_flight.fetch_update(
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                        |v| Some(v.saturating_sub(1)),
                    );
                    return Ok(TryRecv::Item(item));
                }
                TryRecv::Empty => any_open = true,
                TryRecv::Closed => self.sources[i].open = false,
            }
        }
        Ok(if any_open { TryRecv::Empty } else { TryRecv::Closed })
    }

    /// Publish this replica's pending admission-queue depth for the
    /// producers' least-depth routing (scheduler feedback).
    pub fn publish_queue_depth(&self, depth: usize) {
        self.load.queue_depth.store(depth, Ordering::Relaxed);
    }

    /// Number of producer replicas feeding this receiver.
    pub fn fanin(&self) -> usize {
        self.sources.len()
    }
}

/// Wire one routed edge: `n_from` producer replicas to `n_to` consumer
/// replicas over `kind` transports.  Returns one [`RouterTx`] per
/// producer replica and one [`RouterRx`] per consumer replica.
/// `routing` may be [`RoutingKind::Auto`]; it resolves against `n_to`.
pub fn wire(
    kind: ConnectorKind,
    routing: RoutingKind,
    label: &str,
    store_addr: Option<&str>,
    n_from: usize,
    n_to: usize,
) -> Result<(Vec<RouterTx>, Vec<RouterRx>)> {
    anyhow::ensure!(n_from >= 1 && n_to >= 1, "edge `{label}`: empty replica set");
    let routing = routing.resolve(n_to);
    let loads: Vec<Arc<ReplicaLoad>> =
        (0..n_to).map(|_| Arc::new(ReplicaLoad::default())).collect();
    let mut txs: Vec<Vec<ConnectorTx>> = (0..n_from).map(|_| Vec::with_capacity(n_to)).collect();
    let mut rxs: Vec<Vec<ConnectorRx>> = (0..n_to).map(|_| Vec::with_capacity(n_from)).collect();
    for (f, row) in txs.iter_mut().enumerate() {
        for (t, col) in rxs.iter_mut().enumerate() {
            // Unique label per underlying channel (shm segment names
            // derive from it).
            let (tx, rx) = pair(kind, &format!("{label}_f{f}t{t}"), store_addr)?;
            row.push(tx);
            col.push(rx);
        }
    }
    let router_txs = txs
        .into_iter()
        .map(|targets| RouterTx {
            targets,
            loads: loads.clone(),
            state: match routing {
                RoutingKind::RoundRobin => RouteState::RoundRobin { next: 0 },
                RoutingKind::LeastDepth => RouteState::LeastDepth,
                RoutingKind::Affinity => RouteState::Affinity,
                RoutingKind::Auto => unreachable!("resolve() never returns Auto"),
            },
        })
        .collect();
    let router_rxs = rxs
        .into_iter()
        .zip(loads)
        .map(|(sources, load)| RouterRx {
            sources: sources.into_iter().map(|rx| Source { rx, open: true }).collect(),
            load,
            next: 0,
        })
        .collect();
    Ok((router_txs, router_rxs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    fn item(req: u64) -> StageItem {
        StageItem::new(req).with("tokens", HostTensor::i32(vec![1], vec![req as i32]))
    }

    fn drain(rx: &mut RouterRx) -> Vec<u64> {
        let mut out = vec![];
        while let TryRecv::Item(it) = rx.try_recv().unwrap() {
            out.push(it.req_id);
        }
        out
    }

    #[test]
    fn round_robin_rotates_across_replicas_in_order() {
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::RoundRobin, "rr", None, 1, 3).unwrap();
        for req in 0..6 {
            txs[0].send(item(req)).unwrap();
        }
        // Strict rotation: replica r gets items r, r+3.
        assert_eq!(drain(&mut rxs[0]), vec![0, 3]);
        assert_eq!(drain(&mut rxs[1]), vec![1, 4]);
        assert_eq!(drain(&mut rxs[2]), vec![2, 5]);
    }

    #[test]
    fn least_depth_picks_the_shallower_queue() {
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::LeastDepth, "ld", None, 1, 2).unwrap();
        // Equal load: ties break to replica 0; its in-flight count then
        // steers the next item to replica 1.
        txs[0].send(item(1)).unwrap();
        txs[0].send(item(2)).unwrap();
        assert_eq!(drain(&mut rxs[0]), vec![1]);
        assert_eq!(drain(&mut rxs[1]), vec![2]);
        // Scheduler feedback: replica 0 reports a deep admission queue, so
        // new items avoid it even though its connector is drained.
        rxs[0].publish_queue_depth(10);
        txs[0].send(item(3)).unwrap();
        txs[0].send(item(4)).unwrap();
        assert_eq!(drain(&mut rxs[0]), Vec::<u64>::new());
        assert_eq!(drain(&mut rxs[1]), vec![3, 4]);
        // Feedback clears: replica 0 is eligible again.
        rxs[0].publish_queue_depth(0);
        txs[0].send(item(5)).unwrap();
        assert_eq!(drain(&mut rxs[0]), vec![5]);
    }

    #[test]
    fn affinity_keeps_every_chunk_of_a_request_on_one_replica() {
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::Affinity, "aff", None, 1, 2).unwrap();
        // Interleaved chunks of requests 7 and 8.
        for req in [7u64, 8, 7, 8, 7] {
            txs[0].send(item(req)).unwrap();
        }
        // 7 % 2 == 1, 8 % 2 == 0: each request's whole stream is sticky.
        assert_eq!(drain(&mut rxs[0]), vec![8, 8]);
        assert_eq!(drain(&mut rxs[1]), vec![7, 7, 7]);
    }

    #[test]
    fn affinity_is_consistent_across_producer_replicas() {
        // Two producer replicas route the same request id to the SAME
        // consumer replica (modulo routing is stateless and global).
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::Affinity, "aff2", None, 2, 2).unwrap();
        txs[0].send(item(5)).unwrap();
        txs[1].send(item(5)).unwrap();
        assert_eq!(drain(&mut rxs[0]), Vec::<u64>::new());
        assert_eq!(drain(&mut rxs[1]), vec![5, 5]);
    }

    #[test]
    fn fan_in_merges_producers_and_closes_only_when_all_hang_up() {
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::RoundRobin, "fi", None, 2, 1).unwrap();
        txs[0].send(item(1)).unwrap();
        txs[1].send(item(2)).unwrap();
        let rx = &mut rxs[0];
        assert_eq!(rx.fanin(), 2);
        let mut got = drain(rx);
        got.sort_unstable();
        assert_eq!(got, vec![1, 2]);
        // One producer hangs up: edge still open.
        let tx1 = txs.pop().unwrap();
        drop(tx1);
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Empty));
        txs[0].send(item(3)).unwrap();
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Item(_)));
        // Last producer hangs up: edge closed.
        drop(txs);
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Closed));
    }

    #[test]
    fn single_replica_edge_degenerates_to_pass_through() {
        // Auto routing + one consumer replica: every item flows 1:1, the
        // pre-router behaviour.
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::Auto, "pt", None, 1, 1).unwrap();
        assert_eq!(txs[0].fanout(), 1);
        for req in 0..5 {
            txs[0].send(item(req)).unwrap();
        }
        assert_eq!(drain(&mut rxs[0]), vec![0, 1, 2, 3, 4]);
        assert_eq!(txs[0].bytes_sent(), 5 * 4, "5 i32 payloads over the inline plane");
    }

    #[test]
    fn routed_edge_works_over_shm_transport() {
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Shm, RoutingKind::Affinity, "rshm", None, 1, 2).unwrap();
        for req in [10u64, 11, 10] {
            txs[0].send(item(req)).unwrap();
        }
        assert_eq!(drain(&mut rxs[0]), vec![10, 10]);
        assert_eq!(drain(&mut rxs[1]), vec![11]);
    }
}
