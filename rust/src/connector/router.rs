//! Routed connector layer: 1→N fan-out and N→1 fan-in over the
//! point-to-point connectors in [`super`] (paper §3.3 "flexible GPU
//! allocation"), with **runtime-mutable endpoints** for the elastic
//! autoscaler ([`crate::serving`]).
//!
//! When a stage runs `replicas > 1` engine threads, every edge touching
//! it becomes a *routed* edge: each producer replica owns a [`RouterTx`]
//! that picks a consumer replica per item, and each consumer replica owns
//! a [`RouterRx`] that merges the channels arriving from every producer
//! replica.  An edge between an `m`-replica producer and an `n`-replica
//! consumer is therefore `m × n` underlying connectors, all sharing the
//! transport ([`ConnectorKind`]) configured for the edge.
//!
//! Routing policies ([`RoutingKind`]):
//!
//! * **round-robin** — per-item rotation; maximal spread, only correct
//!   when items are independent (single-item requests).
//! * **least-depth** — per-item pick of the replica with the smallest
//!   load signal: connector in-flight count plus the consumer's
//!   *published* admission-queue depth (the stage thread exports its
//!   [`crate::scheduler::StageScheduler`] queue length through
//!   [`RouterRx::publish_queue_depth`] — the `SchedStats` feedback loop).
//! * **affinity** — per-request stickiness: the first item of a request
//!   picks `req_id % live_replicas` and the assignment is recorded in a
//!   sticky table shared by every producer replica of the edge, so a
//!   request's streamed chunks, conditioning rows, and KV/sequence state
//!   all live on one replica even while the replica set changes.  The
//!   entry is dropped when the request's `finished` item passes, which is
//!   also what lets a draining replica quiesce.  Required for replicated
//!   AR consumers (validated at config load).
//! * **cache-aware** — affinity stickiness with a cache-directed first
//!   pick (the global prefix cache, ISSUE 7): each consumer replica
//!   advertises the prompt signatures its KV prefix cache covers
//!   ([`RouterRx::publish_prefix_cover`]), producers hint a request's
//!   signature before its first item ([`RouterTx::hint_prompt_signature`]),
//!   and the first pick prefers the least-loaded *covering* replica — the
//!   one that can skip the prefill — falling back to least-depth when no
//!   replica covers the prompt (or no hint was given).  Every later item
//!   follows the sticky table exactly like affinity.
//!
//! With one consumer replica every policy degenerates to pass-through,
//! which keeps single-replica pipelines behaviour-identical to the
//! pre-router point-to-point design.
//!
//! # Dynamic endpoints ([`EdgeCtl`])
//!
//! The autoscaler scales a stage by mutating its edges at runtime through
//! the edge's [`EdgeCtl`] handle:
//!
//! * [`EdgeCtl::add_consumer`] / [`EdgeCtl::add_producer`] — wire a new
//!   replica into the edge (new point-to-point channels to/from every
//!   existing peer replica).
//! * [`EdgeCtl::drain_consumer`] — stop routing *new* requests to a
//!   replica; items of requests already assigned to it (affinity) keep
//!   flowing so in-flight state is never stranded.
//! * [`EdgeCtl::consumer_quiesced`] — true once nothing is in flight to
//!   the replica, its published admission queue is empty, and no sticky
//!   request is still assigned to it (drain-before-retire).
//! * [`EdgeCtl::remove_consumer`] / [`EdgeCtl::remove_producer`] — detach
//!   the replica's channels (a removed consumer's senders drop, so its
//!   receiver drains and reports closed).

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::{ConnectorKind, RoutingKind, TransportConfig};
use crate::engine::StageItem;
use crate::event_core::{WakeSet, WAKE_CLOSE, WAKE_EDGE};

use super::{pair_with, ConnectorRx, ConnectorTx, EdgeTransferSnapshot, EdgeTransferStats, TryRecv};

/// Shared load signal for one consumer replica of one edge.
///
/// * `in_flight` — items sent into the replica's channels and not yet
///   received (maintained by the router itself).
/// * `queue_depth` — the consumer stage thread's pending admission-queue
///   length, published each loop iteration (scheduler feedback).
#[derive(Debug, Default)]
pub struct ReplicaLoad {
    in_flight: AtomicUsize,
    queue_depth: AtomicUsize,
    /// Prompt signatures the replica's prefix cache covers, published by
    /// the consumer stage thread (cache-aware routing).
    cover: Mutex<HashSet<u64>>,
    /// The consumer stage thread's wake mailbox (event core), registered
    /// once at thread start via [`RouterRx::register_wake`]; producers
    /// signal it on every push and on edge close, so the thread parks
    /// between items instead of polling.
    wake: Mutex<Option<Arc<WakeSet>>>,
}

impl ReplicaLoad {
    /// Combined depth the least-depth policy ranks replicas by.
    fn score(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed) + self.queue_depth.load(Ordering::Relaxed)
    }

    fn covers(&self, sig: u64) -> bool {
        self.cover.lock().unwrap().contains(&sig)
    }

    fn wake(&self, mask: u64) {
        if let Some(w) = self.wake.lock().unwrap().as_ref() {
            w.wake(mask);
        }
    }
}

/// Sticky request→endpoint assignments, shared by every producer replica
/// of one affinity-routed edge.
type StickyMap = Mutex<HashMap<u64, u64>>;

/// Pending request→prompt-signature hints for cache-aware first picks,
/// shared by every producer replica of the edge and consumed when the
/// request's first item is routed.
type HintMap = Mutex<HashMap<u64, u64>>;

/// One consumer-replica endpoint as a producer replica sees it.
struct Endpoint {
    /// Edge-unique consumer id (never reused across the edge's life).
    uid: u64,
    tx: ConnectorTx,
    load: Arc<ReplicaLoad>,
    /// Shared with the [`EdgeCtl`]: set when the consumer is draining.
    draining: Arc<AtomicBool>,
}

/// The mutable interior of a [`RouterTx`], shared with the edge's
/// [`EdgeCtl`] so endpoints can be added/removed at runtime.
struct TxShared {
    eps: Vec<Endpoint>,
    /// Payload bytes of endpoints that were retired (their per-connector
    /// counters would otherwise vanish with them).
    retired_bytes: u64,
}

enum RouteState {
    RoundRobin { next: usize },
    LeastDepth,
    Affinity,
    CacheAware,
}

/// Fan-out sender owned by one producer replica: one [`ConnectorTx`] per
/// consumer replica, with the routing policy choosing the target per
/// item.
pub struct RouterTx {
    shared: Arc<Mutex<TxShared>>,
    state: RouteState,
    sticky: Arc<StickyMap>,
    hints: Arc<HintMap>,
}

/// Index of the `k`-th non-draining endpoint (`k < n_live`); with no
/// live endpoint (transient during a forced teardown) the full set is
/// used so nothing is lost.  Allocation-free — this runs per item.
fn nth_routable(eps: &[Endpoint], n_live: usize, k: usize) -> usize {
    if n_live == 0 {
        return k % eps.len();
    }
    let mut seen = 0usize;
    for (i, e) in eps.iter().enumerate() {
        if !e.draining.load(Ordering::Relaxed) {
            if seen == k {
                return i;
            }
            seen += 1;
        }
    }
    unreachable!("k out of range of live endpoints")
}

/// Cache-aware first pick: least-loaded live endpoint whose advertised
/// prefix cover contains `sig`; least-loaded live endpoint otherwise
/// (the least-depth fallback).  Draining endpoints are only used when
/// nothing else is live (transient teardown, like `nth_routable`).
fn pick_cache_aware(eps: &[Endpoint], n_live: usize, sig: Option<u64>) -> usize {
    let live = |e: &Endpoint| n_live == 0 || !e.draining.load(Ordering::Relaxed);
    if let Some(sig) = sig {
        let covering = eps
            .iter()
            .enumerate()
            .filter(|(_, e)| live(e) && e.load.covers(sig))
            .min_by_key(|(_, e)| (e.load.score(), e.uid))
            .map(|(i, _)| i);
        if let Some(i) = covering {
            return i;
        }
    }
    eps.iter()
        .enumerate()
        .filter(|(_, e)| live(e))
        .min_by_key(|(_, e)| (e.load.score(), e.uid))
        .map(|(i, _)| i)
        .expect("router has at least one endpoint")
}

impl RouterTx {
    /// Route `item` to one consumer replica.
    pub fn send(&mut self, item: StageItem) -> Result<()> {
        let mut guard = self.shared.lock().unwrap();
        let sh = &mut *guard;
        anyhow::ensure!(!sh.eps.is_empty(), "router edge has no consumer endpoints");
        // New work only routes to non-draining endpoints.
        let n_live =
            sh.eps.iter().filter(|e| !e.draining.load(Ordering::Relaxed)).count();
        let spread = if n_live == 0 { sh.eps.len() } else { n_live };
        let mut finished_sticky: Option<u64> = None;
        let i = match &mut self.state {
            RouteState::RoundRobin { next } => {
                let k = *next % spread;
                *next = (k + 1) % spread;
                nth_routable(&sh.eps, n_live, k)
            }
            RouteState::LeastDepth => {
                let mut best: Option<usize> = None;
                for (i, e) in sh.eps.iter().enumerate() {
                    if n_live > 0 && e.draining.load(Ordering::Relaxed) {
                        continue;
                    }
                    let better = match best {
                        None => true,
                        Some(b) => {
                            (e.load.score(), e.uid)
                                < (sh.eps[b].load.score(), sh.eps[b].uid)
                        }
                    };
                    if better {
                        best = Some(i);
                    }
                }
                best.expect("router has at least one endpoint")
            }
            RouteState::Affinity => {
                let req = item.req_id;
                let mut sticky = self.sticky.lock().unwrap();
                let assigned = sticky.get(&req).and_then(|&uid| {
                    sh.eps.iter().position(|e| e.uid == uid)
                });
                let i = match assigned {
                    Some(i) => i,
                    None => {
                        // First item of the request (or its endpoint was
                        // force-removed): assign among live endpoints.
                        let i =
                            nth_routable(&sh.eps, n_live, (req % spread as u64) as usize);
                        sticky.insert(req, sh.eps[i].uid);
                        i
                    }
                };
                if item.finished {
                    // Last item of the request on this edge: clear the
                    // assignment AFTER the in-flight count is up (below),
                    // so a drain check can never observe "no sticky
                    // request, nothing in flight" mid-send.
                    finished_sticky = Some(req);
                }
                i
            }
            RouteState::CacheAware => {
                let req = item.req_id;
                let mut sticky = self.sticky.lock().unwrap();
                let assigned = sticky.get(&req).and_then(|&uid| {
                    sh.eps.iter().position(|e| e.uid == uid)
                });
                let i = match assigned {
                    Some(i) => i,
                    None => {
                        // First item: steer to the replica whose prefix
                        // cache covers the hinted prompt signature.
                        let sig = self.hints.lock().unwrap().remove(&req);
                        let i = pick_cache_aware(&sh.eps, n_live, sig);
                        sticky.insert(req, sh.eps[i].uid);
                        i
                    }
                };
                if item.finished {
                    finished_sticky = Some(req);
                }
                i
            }
        };
        // Count before sending so a racing consumer can never observe a
        // receive without the matching increment (underflow) — and before
        // the sticky entry clears, so quiescence is never observed early.
        sh.eps[i].load.in_flight.fetch_add(1, Ordering::Relaxed);
        if let Some(req) = finished_sticky {
            self.sticky.lock().unwrap().remove(&req);
        }
        if let Err(e) = sh.eps[i].tx.send(item) {
            let _ = sh.eps[i].load.in_flight.fetch_update(
                Ordering::Relaxed,
                Ordering::Relaxed,
                |v| Some(v.saturating_sub(1)),
            );
            return Err(e);
        }
        // Unpark the chosen consumer's stage thread (event core): the
        // item is in its channel, so a parked worker picks it up at once.
        sh.eps[i].load.wake(WAKE_EDGE);
        Ok(())
    }

    /// Total bytes moved through this producer replica's payload planes
    /// (including through endpoints retired since).
    pub fn bytes_sent(&self) -> u64 {
        let sh = self.shared.lock().unwrap();
        sh.retired_bytes + sh.eps.iter().map(|e| e.tx.bytes_sent).sum::<u64>()
    }

    /// Number of consumer replicas this sender currently fans out to.
    pub fn fanout(&self) -> usize {
        self.shared.lock().unwrap().eps.len()
    }

    /// Record the prompt signature of a request *before* its first item
    /// is sent, so a cache-aware first pick can match it against the
    /// consumers' advertised prefix covers.  No-op for other policies
    /// (the hint is simply never consumed... and cleared on purge).
    pub fn hint_prompt_signature(&self, req_id: u64, sig: u64) {
        if matches!(self.state, RouteState::CacheAware) {
            self.hints.lock().unwrap().insert(req_id, sig);
        }
    }
}

impl Drop for RouterTx {
    /// Close-wake every consumer when the producer replica's thread
    /// exits, so a parked downstream worker observes the closed edge and
    /// runs its drain-and-flush path exactly once instead of sleeping
    /// forever (the never-flush hazard).  When this sender holds the
    /// last reference to its channel set (the edge control plane already
    /// forgot the producer, or never retained it), the senders are
    /// dropped HERE, before the wake, so the woken consumer sees
    /// `Closed` on its very next poll; otherwise the channels stay open
    /// (the edge may still wire this producer to new consumers) and the
    /// wake is a harmless hint.
    fn drop(&mut self) {
        let mut loads: Vec<Arc<ReplicaLoad>> = Vec::new();
        if let Ok(mut sh) = self.shared.lock() {
            if Arc::strong_count(&self.shared) == 1 {
                let eps = std::mem::take(&mut sh.eps);
                for ep in eps {
                    sh.retired_bytes += ep.tx.bytes_sent;
                    loads.push(ep.load.clone());
                    // `ep.tx` drops here: the channel closes.
                }
            } else {
                loads.extend(sh.eps.iter().map(|e| e.load.clone()));
            }
        }
        for l in loads {
            l.wake(WAKE_CLOSE);
        }
    }
}

struct Source {
    rx: ConnectorRx,
}

/// Fan-in receiver owned by one consumer replica: merges the channels
/// from every producer replica, polling them round-robin for fairness.
/// The source list is shared with the edge's [`EdgeCtl`] so producers
/// added at runtime reach existing consumers.
pub struct RouterRx {
    sources: Arc<Mutex<Vec<Source>>>,
    load: Arc<ReplicaLoad>,
    next: usize,
}

impl RouterRx {
    /// Non-blocking receive across all producer replicas.
    /// [`TryRecv::Closed`] only once EVERY producer has hung up and all
    /// channels are drained (closed sources are pruned from the set, so
    /// a retired producer stops being polled).
    pub fn try_recv(&mut self) -> Result<TryRecv> {
        let mut sources = self.sources.lock().unwrap();
        let n = sources.len();
        if n == 0 {
            return Ok(TryRecv::Closed);
        }
        let mut closed: Vec<usize> = vec![];
        let mut got: Option<StageItem> = None;
        for k in 0..n {
            let i = (self.next + k) % n;
            match sources[i].rx.try_recv()? {
                TryRecv::Item(item) => {
                    self.next = (i + 1) % n;
                    let _ = self.load.in_flight.fetch_update(
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                        |v| Some(v.saturating_sub(1)),
                    );
                    got = Some(item);
                    break;
                }
                TryRecv::Empty => {}
                TryRecv::Closed => closed.push(i),
            }
        }
        if !closed.is_empty() {
            closed.sort_unstable_by(|a, b| b.cmp(a));
            for i in closed {
                sources.remove(i);
            }
            self.next = 0; // indices shifted; restart the fairness scan
        }
        Ok(match got {
            Some(item) => TryRecv::Item(item),
            None if sources.is_empty() => TryRecv::Closed,
            None => TryRecv::Empty,
        })
    }

    /// Publish this replica's pending admission-queue depth for the
    /// producers' least-depth routing (scheduler feedback) and the
    /// autoscaler's drain check.
    pub fn publish_queue_depth(&self, depth: usize) {
        self.load.queue_depth.store(depth, Ordering::Relaxed);
    }

    /// Publish the prompt signatures this replica's prefix cache covers
    /// (replaces the previous advertisement).  Producers' cache-aware
    /// first picks match hinted signatures against this set.
    pub fn publish_prefix_cover(&self, cover: &[u64]) {
        let mut c = self.load.cover.lock().unwrap();
        c.clear();
        c.extend(cover.iter().copied());
    }

    /// Number of producer replicas currently feeding this receiver.
    pub fn fanin(&self) -> usize {
        self.sources.lock().unwrap().len()
    }

    /// Register the consuming stage thread's wake mailbox (event core):
    /// producers signal it after every push and when the edge closes, so
    /// the thread parks at idle instead of polling this receiver.
    pub fn register_wake(&self, wake: Arc<WakeSet>) {
        *self.load.wake.lock().unwrap() = Some(wake);
    }
}

struct ProducerEntry {
    uid: u64,
    shared: Arc<Mutex<TxShared>>,
}

struct ConsumerEntry {
    uid: u64,
    sources: Arc<Mutex<Vec<Source>>>,
    load: Arc<ReplicaLoad>,
    draining: Arc<AtomicBool>,
}

#[derive(Default)]
struct EdgeState {
    producers: Vec<ProducerEntry>,
    consumers: Vec<ConsumerEntry>,
}

/// Control handle for one routed edge: owns the endpoint topology and
/// mutates it at runtime (the autoscaler's lever on the data plane).
pub struct EdgeCtl {
    kind: ConnectorKind,
    /// Resolved routing policy (never [`RoutingKind::Auto`]).
    routing: RoutingKind,
    label: String,
    store_addr: Option<String>,
    /// Liveness knobs passed to every channel the edge wires (ISSUE 8).
    transport: TransportConfig,
    /// Per-edge transfer counters, shared by every channel of the edge.
    stats: Arc<EdgeTransferStats>,
    sticky: Arc<StickyMap>,
    hints: Arc<HintMap>,
    state: Mutex<EdgeState>,
    next_uid: AtomicU64,
}

impl EdgeCtl {
    /// Create an empty edge.  `routing` must already be resolved — pass
    /// [`RoutingKind::Affinity`] for elastic edges (always safe; identical
    /// to pass-through at one replica) or `routing.resolve(n_to)` for
    /// statically wired ones.
    pub fn new(
        kind: ConnectorKind,
        routing: RoutingKind,
        label: &str,
        store_addr: Option<&str>,
    ) -> Self {
        debug_assert!(routing != RoutingKind::Auto, "edge `{label}`: unresolved routing");
        Self {
            kind,
            routing,
            label: label.to_string(),
            store_addr: store_addr.map(|s| s.to_string()),
            transport: TransportConfig::default(),
            stats: Arc::new(EdgeTransferStats::default()),
            sticky: Arc::new(Mutex::new(HashMap::new())),
            hints: Arc::new(Mutex::new(HashMap::new())),
            state: Mutex::new(EdgeState::default()),
            next_uid: AtomicU64::new(0),
        }
    }

    /// Set the transport liveness knobs for every channel wired AFTER
    /// this call (builder-style, before the first endpoint is added).
    pub fn with_transport(mut self, transport: &TransportConfig) -> Self {
        self.transport = *transport;
        self
    }

    /// Point-in-time per-edge transfer counters, labelled with the edge
    /// name (`StageSummary`/`RunReport` rollups and the `stats` op).
    pub fn transfer_snapshot(&self) -> EdgeTransferSnapshot {
        let mut s = self.stats.snapshot();
        s.label = self.label.clone();
        s
    }

    fn route_state(&self) -> RouteState {
        match self.routing {
            RoutingKind::RoundRobin => RouteState::RoundRobin { next: 0 },
            RoutingKind::LeastDepth => RouteState::LeastDepth,
            RoutingKind::Affinity => RouteState::Affinity,
            RoutingKind::CacheAware => RouteState::CacheAware,
            RoutingKind::Auto => unreachable!("EdgeCtl::new rejects Auto"),
        }
    }

    /// Wire a new consumer replica into the edge: one fresh channel from
    /// every existing producer replica.  Returns the replica's receiver
    /// and its edge-unique id.
    pub fn add_consumer(&self) -> Result<(RouterRx, u64)> {
        let mut st = self.state.lock().unwrap();
        let uid = self.next_uid.fetch_add(1, Ordering::Relaxed);
        let load = Arc::new(ReplicaLoad::default());
        let draining = Arc::new(AtomicBool::new(false));
        let sources: Arc<Mutex<Vec<Source>>> = Arc::new(Mutex::new(Vec::new()));
        for p in &st.producers {
            let (tx, rx) = pair_with(
                self.kind,
                &format!("{}_p{}c{}", self.label, p.uid, uid),
                self.store_addr.as_deref(),
                &self.transport,
                Some(self.stats.clone()),
            )?;
            p.shared.lock().unwrap().eps.push(Endpoint {
                uid,
                tx,
                load: load.clone(),
                draining: draining.clone(),
            });
            sources.lock().unwrap().push(Source { rx });
        }
        st.consumers.push(ConsumerEntry {
            uid,
            sources: sources.clone(),
            load: load.clone(),
            draining,
        });
        Ok((RouterRx { sources, load, next: 0 }, uid))
    }

    /// Wire a new producer replica into the edge: one fresh channel to
    /// every existing consumer replica.  Returns the replica's sender and
    /// its edge-unique id.
    pub fn add_producer(&self) -> Result<(RouterTx, u64)> {
        let mut st = self.state.lock().unwrap();
        let uid = self.next_uid.fetch_add(1, Ordering::Relaxed);
        let shared = Arc::new(Mutex::new(TxShared { eps: Vec::new(), retired_bytes: 0 }));
        for c in &st.consumers {
            let (tx, rx) = pair_with(
                self.kind,
                &format!("{}_p{}c{}", self.label, uid, c.uid),
                self.store_addr.as_deref(),
                &self.transport,
                Some(self.stats.clone()),
            )?;
            shared.lock().unwrap().eps.push(Endpoint {
                uid: c.uid,
                tx,
                load: c.load.clone(),
                draining: c.draining.clone(),
            });
            c.sources.lock().unwrap().push(Source { rx });
        }
        st.producers.push(ProducerEntry { uid, shared: shared.clone() });
        Ok((
            RouterTx {
                shared,
                state: self.route_state(),
                sticky: self.sticky.clone(),
                hints: self.hints.clone(),
            },
            uid,
        ))
    }

    /// Stop routing new requests to consumer `uid` (drain-before-retire
    /// step 1).  Items of requests already assigned to it keep flowing.
    pub fn drain_consumer(&self, uid: u64) {
        let st = self.state.lock().unwrap();
        if let Some(c) = st.consumers.iter().find(|c| c.uid == uid) {
            c.draining.store(true, Ordering::Relaxed);
        }
    }

    /// Whether a draining consumer has fully quiesced on this edge:
    /// no sticky request is still assigned to it, nothing is in flight
    /// in its channels, and its published admission queue is empty.
    ///
    /// Order matters: the sticky table is checked FIRST (under its
    /// lock).  A producer finishing a request bumps the endpoint's
    /// in-flight count *before* clearing the sticky entry, so once this
    /// lock observes the entry gone, the matching in-flight increment is
    /// visible too — the final item can never slip past both checks.
    pub fn consumer_quiesced(&self, uid: u64) -> bool {
        let st = self.state.lock().unwrap();
        let Some(c) = st.consumers.iter().find(|c| c.uid == uid) else { return true };
        if self.sticky.lock().unwrap().values().any(|&v| v == uid) {
            return false;
        }
        c.load.in_flight.load(Ordering::Relaxed) == 0
            && c.load.queue_depth.load(Ordering::Relaxed) == 0
    }

    /// Detach consumer `uid` from every producer (drain-before-retire
    /// step 2).  Dropping the senders closes the replica's channels, so
    /// its receiver drains whatever is left and then reports closed.
    pub fn remove_consumer(&self, uid: u64) {
        let mut st = self.state.lock().unwrap();
        let load = st.consumers.iter().find(|c| c.uid == uid).map(|c| c.load.clone());
        for p in &st.producers {
            let mut sh = p.shared.lock().unwrap();
            let mut kept = Vec::with_capacity(sh.eps.len());
            for ep in sh.eps.drain(..) {
                if ep.uid == uid {
                    sh.retired_bytes += ep.tx.bytes_sent;
                } else {
                    kept.push(ep);
                }
            }
            sh.eps = kept;
        }
        st.consumers.retain(|c| c.uid != uid);
        drop(st);
        // The detached replica's receiver now reports `Closed` once
        // drained: wake its (possibly parked) thread so the close is
        // observed immediately rather than at the liveness backstop.
        if let Some(l) = load {
            l.wake(WAKE_CLOSE);
        }
    }

    /// Forget producer `uid`.  The producer's own [`RouterTx`] drop (on
    /// thread exit) is what actually closes its channels; consumers prune
    /// the closed sources on their next poll.
    pub fn remove_producer(&self, uid: u64) {
        let mut st = self.state.lock().unwrap();
        st.producers.retain(|p| p.uid != uid);
    }

    /// Drop any sticky assignment for `req_id` (end-to-end cancellation:
    /// a cancelled request's `finished` item never flows through the
    /// edge, so without this its affinity entry would live forever —
    /// leaking per-request state and pinning a draining replica, which
    /// could then never quiesce).
    pub fn purge_request(&self, req_id: u64) {
        self.sticky.lock().unwrap().remove(&req_id);
        // A request cancelled before its first item routed would
        // otherwise leak its cache-aware hint.
        self.hints.lock().unwrap().remove(&req_id);
    }

    /// Live (non-draining) consumer replica count.
    pub fn live_consumers(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.consumers.iter().filter(|c| !c.draining.load(Ordering::Relaxed)).count()
    }

    pub fn n_consumers(&self) -> usize {
        self.state.lock().unwrap().consumers.len()
    }

    pub fn n_producers(&self) -> usize {
        self.state.lock().unwrap().producers.len()
    }
}

/// Wire one routed edge statically: `n_from` producer replicas to `n_to`
/// consumer replicas over `kind` transports.  Returns one [`RouterTx`]
/// per producer replica and one [`RouterRx`] per consumer replica.
/// `routing` may be [`RoutingKind::Auto`]; it resolves against `n_to`.
pub fn wire(
    kind: ConnectorKind,
    routing: RoutingKind,
    label: &str,
    store_addr: Option<&str>,
    n_from: usize,
    n_to: usize,
) -> Result<(Vec<RouterTx>, Vec<RouterRx>)> {
    anyhow::ensure!(n_from >= 1 && n_to >= 1, "edge `{label}`: empty replica set");
    let ctl = EdgeCtl::new(kind, routing.resolve(n_to), label, store_addr);
    let mut rxs = Vec::with_capacity(n_to);
    for _ in 0..n_to {
        rxs.push(ctl.add_consumer()?.0);
    }
    let mut txs = Vec::with_capacity(n_from);
    for _ in 0..n_from {
        txs.push(ctl.add_producer()?.0);
    }
    Ok((txs, rxs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    fn item(req: u64) -> StageItem {
        StageItem::new(req).with("tokens", HostTensor::i32(vec![1], vec![req as i32]))
    }

    fn drain(rx: &mut RouterRx) -> Vec<u64> {
        let mut out = vec![];
        while let TryRecv::Item(it) = rx.try_recv().unwrap() {
            out.push(it.req_id);
        }
        out
    }

    #[test]
    fn round_robin_rotates_across_replicas_in_order() {
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::RoundRobin, "rr", None, 1, 3).unwrap();
        for req in 0..6 {
            txs[0].send(item(req)).unwrap();
        }
        // Strict rotation: replica r gets items r, r+3.
        assert_eq!(drain(&mut rxs[0]), vec![0, 3]);
        assert_eq!(drain(&mut rxs[1]), vec![1, 4]);
        assert_eq!(drain(&mut rxs[2]), vec![2, 5]);
    }

    #[test]
    fn least_depth_picks_the_shallower_queue() {
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::LeastDepth, "ld", None, 1, 2).unwrap();
        // Equal load: ties break to replica 0; its in-flight count then
        // steers the next item to replica 1.
        txs[0].send(item(1)).unwrap();
        txs[0].send(item(2)).unwrap();
        assert_eq!(drain(&mut rxs[0]), vec![1]);
        assert_eq!(drain(&mut rxs[1]), vec![2]);
        // Scheduler feedback: replica 0 reports a deep admission queue, so
        // new items avoid it even though its connector is drained.
        rxs[0].publish_queue_depth(10);
        txs[0].send(item(3)).unwrap();
        txs[0].send(item(4)).unwrap();
        assert_eq!(drain(&mut rxs[0]), Vec::<u64>::new());
        assert_eq!(drain(&mut rxs[1]), vec![3, 4]);
        // Feedback clears: replica 0 is eligible again.
        rxs[0].publish_queue_depth(0);
        txs[0].send(item(5)).unwrap();
        assert_eq!(drain(&mut rxs[0]), vec![5]);
    }

    #[test]
    fn affinity_keeps_every_chunk_of_a_request_on_one_replica() {
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::Affinity, "aff", None, 1, 2).unwrap();
        // Interleaved chunks of requests 7 and 8.
        for req in [7u64, 8, 7, 8, 7] {
            txs[0].send(item(req)).unwrap();
        }
        // 7 % 2 == 1, 8 % 2 == 0: each request's whole stream is sticky.
        assert_eq!(drain(&mut rxs[0]), vec![8, 8]);
        assert_eq!(drain(&mut rxs[1]), vec![7, 7, 7]);
    }

    #[test]
    fn affinity_is_consistent_across_producer_replicas() {
        // Two producer replicas route the same request id to the SAME
        // consumer replica (the sticky table is shared per edge).
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::Affinity, "aff2", None, 2, 2).unwrap();
        txs[0].send(item(5)).unwrap();
        txs[1].send(item(5)).unwrap();
        assert_eq!(drain(&mut rxs[0]), Vec::<u64>::new());
        assert_eq!(drain(&mut rxs[1]), vec![5, 5]);
    }

    #[test]
    fn cache_aware_first_pick_follows_the_advertised_cover() {
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::CacheAware, "ca", None, 1, 2).unwrap();
        // Replica 1 advertises coverage of signature 0xFEED; the hinted
        // request lands there despite replica 0 winning every tiebreak.
        rxs[1].publish_prefix_cover(&[0xFEED]);
        txs[0].hint_prompt_signature(42, 0xFEED);
        txs[0].send(item(42)).unwrap();
        txs[0].send(item(42)).unwrap(); // sticky follow-up chunk
        assert_eq!(drain(&mut rxs[0]), Vec::<u64>::new());
        assert_eq!(drain(&mut rxs[1]), vec![42, 42]);
        // A hinted but uncovered signature falls back to least depth
        // (equal load: lowest uid wins).
        txs[0].hint_prompt_signature(43, 0xBEEF);
        txs[0].send(item(43).finished()).unwrap();
        assert_eq!(drain(&mut rxs[0]), vec![43]);
        // Unhinted requests also fall back to least depth.
        rxs[0].publish_queue_depth(5);
        txs[0].send(item(44)).unwrap();
        assert_eq!(drain(&mut rxs[1]), vec![44]);
        rxs[0].publish_queue_depth(0);
        // A re-published cover replaces the old advertisement.
        rxs[1].publish_prefix_cover(&[]);
        txs[0].hint_prompt_signature(45, 0xFEED);
        txs[0].send(item(45)).unwrap();
        assert_eq!(drain(&mut rxs[0]), vec![45]);
    }

    #[test]
    fn cache_aware_ignores_the_cover_of_a_draining_replica() {
        let ctl = EdgeCtl::new(ConnectorKind::Inline, RoutingKind::CacheAware, "cadrain", None);
        let (mut rx0, _u0) = ctl.add_consumer().unwrap();
        let (mut rx1, u1) = ctl.add_consumer().unwrap();
        let (mut tx, _p) = ctl.add_producer().unwrap();
        rx1.publish_prefix_cover(&[7]);
        ctl.drain_consumer(u1);
        // The covering replica is draining: a new request must not pin
        // itself to it, cached prefix or not.
        tx.hint_prompt_signature(9, 7);
        tx.send(item(9)).unwrap();
        assert_eq!(drain(&mut rx1), Vec::<u64>::new());
        assert_eq!(drain(&mut rx0), vec![9]);
    }

    #[test]
    fn fan_in_merges_producers_and_closes_only_when_all_hang_up() {
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::RoundRobin, "fi", None, 2, 1).unwrap();
        txs[0].send(item(1)).unwrap();
        txs[1].send(item(2)).unwrap();
        let rx = &mut rxs[0];
        assert_eq!(rx.fanin(), 2);
        let mut got = drain(rx);
        got.sort_unstable();
        assert_eq!(got, vec![1, 2]);
        // One producer hangs up: edge still open.
        let tx1 = txs.pop().unwrap();
        drop(tx1);
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Empty));
        txs[0].send(item(3)).unwrap();
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Item(_)));
        // Last producer hangs up: edge closed.
        drop(txs);
        assert!(matches!(rx.try_recv().unwrap(), TryRecv::Closed));
    }

    #[test]
    fn single_replica_edge_degenerates_to_pass_through() {
        // Auto routing + one consumer replica: every item flows 1:1, the
        // pre-router behaviour.
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::Auto, "pt", None, 1, 1).unwrap();
        assert_eq!(txs[0].fanout(), 1);
        for req in 0..5 {
            txs[0].send(item(req)).unwrap();
        }
        assert_eq!(drain(&mut rxs[0]), vec![0, 1, 2, 3, 4]);
        assert_eq!(txs[0].bytes_sent(), 5 * 4, "5 i32 payloads over the inline plane");
    }

    #[test]
    fn routed_edge_works_over_shm_transport() {
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Shm, RoutingKind::Affinity, "rshm", None, 1, 2).unwrap();
        for req in [10u64, 11, 10] {
            txs[0].send(item(req)).unwrap();
        }
        assert_eq!(drain(&mut rxs[0]), vec![10, 10]);
        assert_eq!(drain(&mut rxs[1]), vec![11]);
    }

    // -----------------------------------------------------------------
    // Dynamic endpoints (the autoscaler's data-plane surface).
    // -----------------------------------------------------------------

    #[test]
    fn added_consumer_starts_receiving_new_requests() {
        let ctl = EdgeCtl::new(ConnectorKind::Inline, RoutingKind::Affinity, "dynadd", None);
        let (mut rx0, _u0) = ctl.add_consumer().unwrap();
        let (mut tx, _p) = ctl.add_producer().unwrap();
        // One consumer: everything lands on it.
        tx.send(item(3)).unwrap();
        assert_eq!(drain(&mut rx0), vec![3]);
        // Scale up: a second consumer joins; new even requests map to one
        // of the two live endpoints deterministically.
        let (mut rx1, _u1) = ctl.add_consumer().unwrap();
        assert_eq!(tx.fanout(), 2);
        tx.send(item(10)).unwrap(); // 10 % 2 == 0 -> first endpoint
        tx.send(item(11)).unwrap(); // 11 % 2 == 1 -> second endpoint
        assert_eq!(drain(&mut rx0), vec![10]);
        assert_eq!(drain(&mut rx1), vec![11]);
    }

    #[test]
    fn added_producer_reaches_existing_consumers() {
        let ctl = EdgeCtl::new(ConnectorKind::Inline, RoutingKind::Affinity, "dynprod", None);
        let (mut rx, _u) = ctl.add_consumer().unwrap();
        let (mut tx0, _p0) = ctl.add_producer().unwrap();
        tx0.send(item(1)).unwrap();
        let (mut tx1, _p1) = ctl.add_producer().unwrap();
        tx1.send(item(2)).unwrap();
        assert_eq!(rx.fanin(), 2);
        let mut got = drain(&mut rx);
        got.sort_unstable();
        assert_eq!(got, vec![1, 2]);
    }

    #[test]
    fn drain_before_retire_with_requests_in_flight() {
        // The satellite scenario: an endpoint is removed while requests
        // are in flight.  Request 1 is sticky on the draining replica and
        // must keep flowing there until its finished item; new requests
        // must avoid the draining replica; only then does it quiesce and
        // get removed.
        let ctl = EdgeCtl::new(ConnectorKind::Inline, RoutingKind::Affinity, "dyndrain", None);
        let (mut rx0, u0) = ctl.add_consumer().unwrap();
        let (mut rx1, _u1) = ctl.add_consumer().unwrap();
        let (mut tx, _p) = ctl.add_producer().unwrap();

        // Request 2 (2 % 2 == 0) starts streaming onto consumer 0.
        tx.send(item(2)).unwrap();
        assert_eq!(drain(&mut rx0), vec![2]);
        ctl.drain_consumer(u0);
        assert!(!ctl.consumer_quiesced(u0), "sticky request 2 still assigned");

        // New request 4 would also hash to consumer 0, but it is
        // draining: the request is assigned to the remaining live one.
        tx.send(item(4)).unwrap();
        assert_eq!(drain(&mut rx1), vec![4]);
        assert_eq!(drain(&mut rx0), Vec::<u64>::new());

        // Request 2's follow-up chunks still reach the draining replica.
        tx.send(item(2)).unwrap();
        tx.send(item(2).finished()).unwrap();
        assert_eq!(drain(&mut rx0), vec![2, 2]);

        // Finished item passed + channels drained: quiesced.
        assert!(ctl.consumer_quiesced(u0));
        ctl.remove_consumer(u0);
        assert_eq!(tx.fanout(), 1);
        // The removed consumer's channels are closed.
        assert!(matches!(rx0.try_recv().unwrap(), TryRecv::Closed));
        // Everything (old and new) now routes to the survivor.
        tx.send(item(6)).unwrap();
        assert_eq!(drain(&mut rx1), vec![6]);
    }

    #[test]
    fn quiesce_waits_for_in_flight_and_published_queue() {
        let ctl = EdgeCtl::new(ConnectorKind::Inline, RoutingKind::RoundRobin, "dynq", None);
        let (mut rx, u) = ctl.add_consumer().unwrap();
        let (mut tx, _p) = ctl.add_producer().unwrap();
        tx.send(item(1).finished()).unwrap();
        ctl.drain_consumer(u);
        assert!(!ctl.consumer_quiesced(u), "item still in flight");
        assert_eq!(drain(&mut rx), vec![1]);
        rx.publish_queue_depth(1);
        assert!(!ctl.consumer_quiesced(u), "admission queue still holds the item");
        rx.publish_queue_depth(0);
        assert!(ctl.consumer_quiesced(u));
    }

    #[test]
    fn purge_request_unpins_a_draining_replica() {
        // A request is sticky on a draining replica and then cancelled:
        // its finished item never flows, so only purge_request lets the
        // replica quiesce.
        let ctl = EdgeCtl::new(ConnectorKind::Inline, RoutingKind::Affinity, "dyncancel", None);
        let (mut rx0, u0) = ctl.add_consumer().unwrap();
        let (_rx1, _u1) = ctl.add_consumer().unwrap();
        let (mut tx, _p) = ctl.add_producer().unwrap();
        tx.send(item(2)).unwrap(); // 2 % 2 == 0 -> consumer 0
        assert_eq!(drain(&mut rx0), vec![2]);
        ctl.drain_consumer(u0);
        assert!(!ctl.consumer_quiesced(u0), "sticky request 2 still assigned");
        ctl.purge_request(2);
        assert!(ctl.consumer_quiesced(u0), "cancellation must unpin the replica");
        // A later item of the purged request re-assigns among LIVE
        // replicas (it is dropped consumer-side by the tombstone check;
        // the router only guarantees it avoids the draining one).
        tx.send(item(2)).unwrap();
        assert_eq!(drain(&mut rx0), Vec::<u64>::new());
    }

    #[test]
    fn transfer_snapshot_aggregates_the_whole_edge() {
        // Stats are per logical edge: two consumers, one producer — every
        // frame lands in one labelled snapshot regardless of the replica
        // it routed to.
        let ctl = EdgeCtl::new(ConnectorKind::Inline, RoutingKind::Affinity, "dynstats", None)
            .with_transport(&TransportConfig::default());
        let (mut rx0, _u0) = ctl.add_consumer().unwrap();
        let (mut rx1, _u1) = ctl.add_consumer().unwrap();
        let (mut tx, _p) = ctl.add_producer().unwrap();
        for req in [2u64, 3, 2] {
            tx.send(item(req)).unwrap();
        }
        drain(&mut rx0);
        drain(&mut rx1);
        let snap = ctl.transfer_snapshot();
        assert_eq!(snap.label, "dynstats");
        assert_eq!(snap.frames, 3);
        assert_eq!(snap.bytes, 3 * 4, "3 i32 payloads over the inline plane");
        assert!(snap.p95_ms >= snap.p50_ms);
    }

    #[test]
    fn retired_endpoint_bytes_stay_in_the_accounting() {
        let ctl = EdgeCtl::new(ConnectorKind::Inline, RoutingKind::Affinity, "dynbytes", None);
        let (mut rx0, u0) = ctl.add_consumer().unwrap();
        let (mut tx, _p) = ctl.add_producer().unwrap();
        tx.send(item(0).finished()).unwrap(); // 4 bytes
        assert_eq!(drain(&mut rx0), vec![0]);
        let (_rx1, _u1) = ctl.add_consumer().unwrap();
        ctl.drain_consumer(u0);
        assert!(ctl.consumer_quiesced(u0));
        ctl.remove_consumer(u0);
        tx.send(item(1).finished()).unwrap(); // 4 more bytes to the survivor
        assert_eq!(tx.bytes_sent(), 8, "retired endpoint's bytes are not lost");
    }

    // -----------------------------------------------------------------
    // Event-core wake hooks (parked consumers, edge-close signalling).
    // -----------------------------------------------------------------

    #[test]
    fn send_wakes_a_parked_consumer_thread() {
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::RoundRobin, "wake", None, 1, 1).unwrap();
        let wake = Arc::new(WakeSet::new());
        rxs[0].register_wake(wake.clone());
        let w = wake.clone();
        let t = std::thread::spawn(move || w.park(std::time::Duration::from_secs(30)));
        std::thread::sleep(std::time::Duration::from_millis(10));
        txs[0].send(item(1)).unwrap();
        let mask = t.join().unwrap();
        assert_eq!(mask & WAKE_EDGE, WAKE_EDGE);
        assert_eq!(drain(&mut rxs[0]), vec![1]);
    }

    #[test]
    fn producer_drop_close_wakes_and_the_flush_happens_exactly_once() {
        // Never-flush regression: a consumer parked on a quiet edge must
        // be woken when its last producer hangs up, and must then observe
        // the remaining items followed by `Closed`.  `Closed` is stable
        // on every further poll — the stage loop flushes on the single
        // open→closed transition and never polls the edge again, so a
        // double flush is impossible.
        let (mut txs, mut rxs) =
            wire(ConnectorKind::Inline, RoutingKind::RoundRobin, "close", None, 1, 1).unwrap();
        txs[0].send(item(7)).unwrap();
        let wake = Arc::new(WakeSet::new());
        rxs[0].register_wake(wake.clone());
        let w = wake.clone();
        let t = std::thread::spawn(move || w.park(std::time::Duration::from_secs(30)));
        std::thread::sleep(std::time::Duration::from_millis(10));
        // `wire` did not retain the edge control plane, so this drop
        // holds the last reference: channels close BEFORE the wake.
        drop(txs);
        let mask = t.join().unwrap();
        assert_eq!(mask & WAKE_CLOSE, WAKE_CLOSE);
        assert!(matches!(rxs[0].try_recv().unwrap(), TryRecv::Item(it) if it.req_id == 7));
        assert!(matches!(rxs[0].try_recv().unwrap(), TryRecv::Closed));
        assert!(matches!(rxs[0].try_recv().unwrap(), TryRecv::Closed));
    }

    #[test]
    fn remove_consumer_close_wakes_the_detached_replica() {
        let ctl = EdgeCtl::new(ConnectorKind::Inline, RoutingKind::Affinity, "rmwake", None);
        let (mut rx0, u0) = ctl.add_consumer().unwrap();
        let (_rx1, _u1) = ctl.add_consumer().unwrap();
        let (_tx, _p) = ctl.add_producer().unwrap();
        let wake = Arc::new(WakeSet::new());
        rx0.register_wake(wake.clone());
        ctl.remove_consumer(u0);
        assert_eq!(wake.try_drain() & WAKE_CLOSE, WAKE_CLOSE);
        assert!(matches!(rx0.try_recv().unwrap(), TryRecv::Closed));
    }
}
