//! Mooncake-like TCP put/get store (paper §3.4: "A Mooncake-based
//! connector ... enabling TCP- or RDMA-based transport, allowing stages
//! on different servers to exchange data via a common put/get interface
//! while passing only lightweight metadata through the control plane").
//!
//! Protocol (little-endian):
//!   PUT: `b'P' | key_len u32 | key | val_len u64 | val`      -> `b'K'`
//!   GET: `b'G' | key_len u32 | key`  -> `b'H'* | b'V' | val_len u64 | val`
//!        (blocks server-side until the key exists, then removes it; a
//!        heartbeat byte `b'H'` is emitted every `heartbeat_s` while the
//!        wait lasts, so a live-but-idle peer is distinguishable from a
//!        dead one)
//!   DEL: `b'D' | key_len u32 | key`  -> `b'K'`
//!        (removes the key if present; never blocks — leak reclamation)
//!
//! Liveness (ISSUE 8): clients set a socket read timeout of
//! [`TransportConfig::read_timeout_s`].  A healthy blocked GET hears a
//! heartbeat well inside that window; total silence (peer process gone
//! without a FIN — the case that used to hang the receiver forever)
//! surfaces as a structured error naming the edge, and an explicit
//! hangup (FIN/RST) errors immediately.
//!
//! One thread per connection; the store is an in-memory map + condvar.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::config::TransportConfig;

struct Shared {
    map: Mutex<HashMap<String, Vec<u8>>>,
    cv: Condvar,
    /// Interval between `b'H'` bytes on a blocked GET.
    heartbeat: Duration,
}

/// The store server.  Dropping the handle leaves the daemon thread
/// running for process lifetime (detached), which is fine for tests and
/// benches; `addr()` gives the bound address.
pub struct MooncakeStore {
    addr: String,
    shared: Arc<Shared>,
}

impl MooncakeStore {
    pub fn spawn(bind: &str) -> Result<Self> {
        Self::spawn_with(bind, &TransportConfig::default())
    }

    /// Spawn with explicit liveness knobs (the serving layer passes the
    /// pipeline's [`TransportConfig`] here).
    pub fn spawn_with(bind: &str, transport: &TransportConfig) -> Result<Self> {
        let listener = TcpListener::bind(bind).context("binding mooncake store")?;
        let addr = listener.local_addr()?.to_string();
        let shared = Arc::new(Shared {
            map: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            heartbeat: Duration::from_secs_f64(transport.heartbeat_s),
        });
        let s2 = shared.clone();
        std::thread::Builder::new()
            .name("mooncake-store".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    let Ok(stream) = conn else { continue };
                    let s3 = s2.clone();
                    std::thread::spawn(move || {
                        let _ = serve_conn(stream, s3);
                    });
                }
            })?;
        Ok(Self { addr, shared })
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Number of keys currently stored (tests / metrics).
    pub fn len(&self) -> usize {
        self.shared.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn serve_conn(mut stream: TcpStream, shared: Arc<Shared>) -> Result<()> {
    stream.set_nodelay(true).ok();
    loop {
        let mut op = [0u8; 1];
        if stream.read_exact(&mut op).is_err() {
            return Ok(()); // client hung up
        }
        match op[0] {
            b'P' => {
                let key = read_key(&mut stream)?;
                let mut len8 = [0u8; 8];
                stream.read_exact(&mut len8)?;
                let vlen = u64::from_le_bytes(len8) as usize;
                let mut val = vec![0u8; vlen];
                stream.read_exact(&mut val)?;
                {
                    let mut map = shared.map.lock().unwrap();
                    map.insert(key, val);
                    shared.cv.notify_all();
                }
                stream.write_all(b"K")?;
            }
            b'G' => {
                let key = read_key(&mut stream)?;
                let val = 'got: loop {
                    {
                        let mut map = shared.map.lock().unwrap();
                        loop {
                            if let Some(v) = map.remove(&key) {
                                break 'got v;
                            }
                            let (guard, timed_out) =
                                shared.cv.wait_timeout(map, shared.heartbeat).unwrap();
                            map = guard;
                            if timed_out.timed_out() {
                                break; // drop the lock before touching the socket
                            }
                        }
                    }
                    // Still waiting: prove liveness to the blocked
                    // client.  A failed write means the client hung up —
                    // stop waiting on its behalf.
                    if stream.write_all(b"H").is_err() {
                        return Ok(());
                    }
                };
                stream.write_all(b"V")?;
                stream.write_all(&(val.len() as u64).to_le_bytes())?;
                stream.write_all(&val)?;
            }
            b'D' => {
                let key = read_key(&mut stream)?;
                shared.map.lock().unwrap().remove(&key);
                stream.write_all(b"K")?;
            }
            other => bail!("mooncake: unknown op {other}"),
        }
    }
}

fn read_key(stream: &mut TcpStream) -> Result<String> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4)?;
    let klen = u32::from_le_bytes(len4) as usize;
    if klen > 4096 {
        bail!("mooncake: key too long");
    }
    let mut key = vec![0u8; klen];
    stream.read_exact(&mut key)?;
    Ok(String::from_utf8(key)?)
}

/// Client handle (one TCP connection; not thread-safe — one per thread).
pub struct StoreClient {
    stream: TcpStream,
    /// Edge name for structured dead-peer errors ("thinker->talker").
    label: String,
}

impl StoreClient {
    pub fn connect(addr: &str) -> Result<Self> {
        Self::connect_with(addr, &TransportConfig::default(), "store")
    }

    /// Connect with explicit liveness knobs and an edge label used in
    /// dead-peer errors.  The socket read timeout is the peer-dead
    /// horizon: a healthy blocked GET hears a heartbeat every
    /// [`TransportConfig::heartbeat_s`], so only true silence trips it.
    pub fn connect_with(addr: &str, transport: &TransportConfig, label: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("edge `{label}`: connecting to mooncake store {addr}"))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs_f64(transport.read_timeout_s)))?;
        Ok(Self { stream, label: label.to_string() })
    }

    /// Map an I/O failure while awaiting the peer into a structured
    /// error naming the dead edge (ISSUE 8 liveness).
    fn dead_peer(&self, key: &str, e: std::io::Error) -> anyhow::Error {
        use std::io::ErrorKind;
        if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) {
            anyhow::anyhow!(
                "edge `{}`: peer dead (no heartbeat within the read timeout) awaiting `{key}`",
                self.label
            )
        } else {
            anyhow::anyhow!("edge `{}`: peer hung up awaiting `{key}`: {e}", self.label)
        }
    }

    pub fn put(&mut self, key: &str, val: &[u8]) -> Result<()> {
        self.stream.write_all(b"P")?;
        self.stream.write_all(&(key.len() as u32).to_le_bytes())?;
        self.stream.write_all(key.as_bytes())?;
        self.stream.write_all(&(val.len() as u64).to_le_bytes())?;
        self.stream.write_all(val)?;
        let mut ack = [0u8; 1];
        self.stream.read_exact(&mut ack)?;
        if ack[0] != b'K' {
            bail!("mooncake: bad PUT ack");
        }
        Ok(())
    }

    /// Non-blocking remove-if-present (idempotent): reclaim a parked
    /// value whose key will never be `get`-resolved.
    pub fn del(&mut self, key: &str) -> Result<()> {
        self.stream.write_all(b"D")?;
        self.stream.write_all(&(key.len() as u32).to_le_bytes())?;
        self.stream.write_all(key.as_bytes())?;
        let mut ack = [0u8; 1];
        self.stream.read_exact(&mut ack)?;
        if ack[0] != b'K' {
            bail!("mooncake: bad DEL ack");
        }
        Ok(())
    }

    /// Blocking get-and-remove.  Waits indefinitely for a HEALTHY peer
    /// (heartbeats keep the socket warm); a silent or hung-up peer
    /// surfaces a structured error naming the edge instead of hanging.
    pub fn get(&mut self, key: &str) -> Result<Vec<u8>> {
        self.stream.write_all(b"G")?;
        self.stream.write_all(&(key.len() as u32).to_le_bytes())?;
        self.stream.write_all(key.as_bytes())?;
        loop {
            let mut tag = [0u8; 1];
            self.stream.read_exact(&mut tag).map_err(|e| self.dead_peer(key, e))?;
            match tag[0] {
                b'H' => continue, // heartbeat: peer alive, value not ready yet
                b'V' => break,
                other => bail!("mooncake: bad GET tag {other}"),
            }
        }
        let mut len8 = [0u8; 8];
        self.stream.read_exact(&mut len8).map_err(|e| self.dead_peer(key, e))?;
        let vlen = u64::from_le_bytes(len8) as usize;
        let mut val = vec![0u8; vlen];
        self.stream.read_exact(&mut val).map_err(|e| self.dead_peer(key, e))?;
        Ok(val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_removes() {
        let store = MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let mut c = StoreClient::connect(store.addr()).unwrap();
        c.put("k1", b"hello").unwrap();
        assert_eq!(store.len(), 1);
        assert_eq!(c.get("k1").unwrap(), b"hello");
        assert_eq!(store.len(), 0);
    }

    #[test]
    fn del_removes_and_is_idempotent() {
        let store = MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let mut c = StoreClient::connect(store.addr()).unwrap();
        c.put("k", b"v").unwrap();
        assert_eq!(store.len(), 1);
        c.del("k").unwrap();
        assert_eq!(store.len(), 0);
        // Missing keys are a no-op, never a block.
        c.del("k").unwrap();
        c.del("never-put").unwrap();
        assert_eq!(store.len(), 0);
    }

    #[test]
    fn get_blocks_until_put() {
        let store = MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let addr = store.addr().to_string();
        let getter = std::thread::spawn(move || {
            let mut c = StoreClient::connect(&addr).unwrap();
            c.get("later").unwrap()
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        let mut c = StoreClient::connect(store.addr()).unwrap();
        c.put("later", b"worth-the-wait").unwrap();
        assert_eq!(getter.join().unwrap(), b"worth-the-wait");
    }

    #[test]
    fn heartbeats_keep_a_slow_put_alive() {
        // The put arrives well AFTER the client's read timeout; only the
        // server heartbeats keep the blocked GET from tripping it.
        let fast = TransportConfig { heartbeat_s: 0.02, read_timeout_s: 0.15 };
        let store = MooncakeStore::spawn_with("127.0.0.1:0", &fast).unwrap();
        let addr = store.addr().to_string();
        let t = fast;
        let getter = std::thread::spawn(move || {
            let mut c = StoreClient::connect_with(&addr, &t, "a->b").unwrap();
            c.get("slow").unwrap()
        });
        std::thread::sleep(Duration::from_millis(400));
        let mut c = StoreClient::connect(store.addr()).unwrap();
        c.put("slow", b"late-but-alive").unwrap();
        assert_eq!(getter.join().unwrap(), b"late-but-alive");
    }

    #[test]
    fn silent_peer_surfaces_structured_timeout_error() {
        // A listener that accepts but never speaks: total silence, the
        // way a wedged/vanished peer looks without a FIN.  The old code
        // blocked forever here.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let hold = std::thread::spawn(move || {
            let conn = listener.accept().map(|(s, _)| s);
            std::thread::sleep(Duration::from_millis(800));
            drop(conn);
        });
        let t = TransportConfig { heartbeat_s: 0.02, read_timeout_s: 0.15 };
        let mut c = StoreClient::connect_with(&addr, &t, "talker->vocoder").unwrap();
        let err = c.get("never").unwrap_err().to_string();
        assert!(err.contains("talker->vocoder"), "error names the edge: {err}");
        assert!(err.contains("peer dead"), "error names the cause: {err}");
        hold.join().unwrap();
    }

    #[test]
    fn hung_up_peer_errors_immediately() {
        // An explicit FIN mid-wait errors right away (no need to burn
        // the whole read timeout).
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let closer = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            std::thread::sleep(Duration::from_millis(30));
            drop(s);
        });
        let t = TransportConfig { heartbeat_s: 0.5, read_timeout_s: 30.0 };
        let start = std::time::Instant::now();
        let mut c = StoreClient::connect_with(&addr, &t, "prefill->decode").unwrap();
        let err = c.get("gone").unwrap_err().to_string();
        assert!(err.contains("prefill->decode"), "error names the edge: {err}");
        assert!(err.contains("hung up"), "error names the cause: {err}");
        assert!(start.elapsed() < Duration::from_secs(5), "no timeout burn on FIN");
        closer.join().unwrap();
    }

    #[test]
    fn large_payload() {
        let store = MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let mut c = StoreClient::connect(store.addr()).unwrap();
        let big: Vec<u8> = (0..2_000_000u32).map(|i| i as u8).collect();
        c.put("big", &big).unwrap();
        assert_eq!(c.get("big").unwrap(), big);
    }
}
