//! Mooncake-like TCP put/get store (paper §3.4: "A Mooncake-based
//! connector ... enabling TCP- or RDMA-based transport, allowing stages
//! on different servers to exchange data via a common put/get interface
//! while passing only lightweight metadata through the control plane").
//!
//! Protocol (little-endian):
//!   PUT: `b'P' | key_len u32 | key | val_len u64 | val`      -> `b'K'`
//!   GET: `b'G' | key_len u32 | key`  -> `b'V' | val_len u64 | val`
//!        (blocks server-side until the key exists, then removes it)
//!   DEL: `b'D' | key_len u32 | key`  -> `b'K'`
//!        (removes the key if present; never blocks — leak reclamation)
//!
//! One thread per connection; the store is an in-memory map + condvar.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Context, Result};

#[derive(Default)]
struct Shared {
    map: Mutex<HashMap<String, Vec<u8>>>,
    cv: Condvar,
}

/// The store server.  Dropping the handle leaves the daemon thread
/// running for process lifetime (detached), which is fine for tests and
/// benches; `addr()` gives the bound address.
pub struct MooncakeStore {
    addr: String,
    shared: Arc<Shared>,
}

impl MooncakeStore {
    pub fn spawn(bind: &str) -> Result<Self> {
        let listener = TcpListener::bind(bind).context("binding mooncake store")?;
        let addr = listener.local_addr()?.to_string();
        let shared = Arc::new(Shared::default());
        let s2 = shared.clone();
        std::thread::Builder::new()
            .name("mooncake-store".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    let Ok(stream) = conn else { continue };
                    let s3 = s2.clone();
                    std::thread::spawn(move || {
                        let _ = serve_conn(stream, s3);
                    });
                }
            })?;
        Ok(Self { addr, shared })
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Number of keys currently stored (tests / metrics).
    pub fn len(&self) -> usize {
        self.shared.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn serve_conn(mut stream: TcpStream, shared: Arc<Shared>) -> Result<()> {
    stream.set_nodelay(true).ok();
    loop {
        let mut op = [0u8; 1];
        if stream.read_exact(&mut op).is_err() {
            return Ok(()); // client hung up
        }
        match op[0] {
            b'P' => {
                let key = read_key(&mut stream)?;
                let mut len8 = [0u8; 8];
                stream.read_exact(&mut len8)?;
                let vlen = u64::from_le_bytes(len8) as usize;
                let mut val = vec![0u8; vlen];
                stream.read_exact(&mut val)?;
                {
                    let mut map = shared.map.lock().unwrap();
                    map.insert(key, val);
                    shared.cv.notify_all();
                }
                stream.write_all(b"K")?;
            }
            b'G' => {
                let key = read_key(&mut stream)?;
                let val = {
                    let mut map = shared.map.lock().unwrap();
                    loop {
                        if let Some(v) = map.remove(&key) {
                            break v;
                        }
                        map = shared.cv.wait(map).unwrap();
                    }
                };
                stream.write_all(b"V")?;
                stream.write_all(&(val.len() as u64).to_le_bytes())?;
                stream.write_all(&val)?;
            }
            b'D' => {
                let key = read_key(&mut stream)?;
                shared.map.lock().unwrap().remove(&key);
                stream.write_all(b"K")?;
            }
            other => bail!("mooncake: unknown op {other}"),
        }
    }
}

fn read_key(stream: &mut TcpStream) -> Result<String> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4)?;
    let klen = u32::from_le_bytes(len4) as usize;
    if klen > 4096 {
        bail!("mooncake: key too long");
    }
    let mut key = vec![0u8; klen];
    stream.read_exact(&mut key)?;
    Ok(String::from_utf8(key)?)
}

/// Client handle (one TCP connection; not thread-safe — one per thread).
pub struct StoreClient {
    stream: TcpStream,
}

impl StoreClient {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).context("connecting to mooncake store")?;
        stream.set_nodelay(true).ok();
        Ok(Self { stream })
    }

    pub fn put(&mut self, key: &str, val: &[u8]) -> Result<()> {
        self.stream.write_all(b"P")?;
        self.stream.write_all(&(key.len() as u32).to_le_bytes())?;
        self.stream.write_all(key.as_bytes())?;
        self.stream.write_all(&(val.len() as u64).to_le_bytes())?;
        self.stream.write_all(val)?;
        let mut ack = [0u8; 1];
        self.stream.read_exact(&mut ack)?;
        if ack[0] != b'K' {
            bail!("mooncake: bad PUT ack");
        }
        Ok(())
    }

    /// Non-blocking remove-if-present (idempotent): reclaim a parked
    /// value whose key will never be `get`-resolved.
    pub fn del(&mut self, key: &str) -> Result<()> {
        self.stream.write_all(b"D")?;
        self.stream.write_all(&(key.len() as u32).to_le_bytes())?;
        self.stream.write_all(key.as_bytes())?;
        let mut ack = [0u8; 1];
        self.stream.read_exact(&mut ack)?;
        if ack[0] != b'K' {
            bail!("mooncake: bad DEL ack");
        }
        Ok(())
    }

    /// Blocking get-and-remove.
    pub fn get(&mut self, key: &str) -> Result<Vec<u8>> {
        self.stream.write_all(b"G")?;
        self.stream.write_all(&(key.len() as u32).to_le_bytes())?;
        self.stream.write_all(key.as_bytes())?;
        let mut tag = [0u8; 1];
        self.stream.read_exact(&mut tag)?;
        if tag[0] != b'V' {
            bail!("mooncake: bad GET tag");
        }
        let mut len8 = [0u8; 8];
        self.stream.read_exact(&mut len8)?;
        let vlen = u64::from_le_bytes(len8) as usize;
        let mut val = vec![0u8; vlen];
        self.stream.read_exact(&mut val)?;
        Ok(val)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_removes() {
        let store = MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let mut c = StoreClient::connect(store.addr()).unwrap();
        c.put("k1", b"hello").unwrap();
        assert_eq!(store.len(), 1);
        assert_eq!(c.get("k1").unwrap(), b"hello");
        assert_eq!(store.len(), 0);
    }

    #[test]
    fn del_removes_and_is_idempotent() {
        let store = MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let mut c = StoreClient::connect(store.addr()).unwrap();
        c.put("k", b"v").unwrap();
        assert_eq!(store.len(), 1);
        c.del("k").unwrap();
        assert_eq!(store.len(), 0);
        // Missing keys are a no-op, never a block.
        c.del("k").unwrap();
        c.del("never-put").unwrap();
        assert_eq!(store.len(), 0);
    }

    #[test]
    fn get_blocks_until_put() {
        let store = MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let addr = store.addr().to_string();
        let getter = std::thread::spawn(move || {
            let mut c = StoreClient::connect(&addr).unwrap();
            c.get("later").unwrap()
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        let mut c = StoreClient::connect(store.addr()).unwrap();
        c.put("later", b"worth-the-wait").unwrap();
        assert_eq!(getter.join().unwrap(), b"worth-the-wait");
    }

    #[test]
    fn large_payload() {
        let store = MooncakeStore::spawn("127.0.0.1:0").unwrap();
        let mut c = StoreClient::connect(store.addr()).unwrap();
        let big: Vec<u8> = (0..2_000_000u32).map(|i| i as u8).collect();
        c.put("big", &big).unwrap();
        assert_eq!(c.get("big").unwrap(), big);
    }
}
