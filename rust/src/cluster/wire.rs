//! Control-plane wire format for the cluster subsystem (ISSUE 8).
//!
//! One frame (`OCTL`), little-endian, carrying every message exchanged
//! between a node agent and the controller:
//!
//! `magic u32 | version u8 | tag u8 | body | fnv1a u64`
//!
//! Strings are `len u32 | utf8 bytes` (bounded — see [`MAX_STR`]); the
//! trailing FNV-1a checksum covers everything before it, so a flipped
//! byte anywhere in the frame is a decode error, never a panic or a
//! silently-wrong assignment (same contract as the `OKVH` KV-handoff
//! frame in [`crate::connector::wire`]).
//!
//! On a TCP stream, frames are length-prefixed (`len u32 | frame`) by
//! [`write_msg`] / [`read_msg`], with the length bounded by
//! [`MAX_FRAME`] so a corrupt prefix cannot OOM the reader.

use std::io::{Read, Write};

use anyhow::{bail, Result};

use crate::connector::EdgeTransferSnapshot;

const MAGIC: u32 = 0x4F43544C; // "OCTL"
const VERSION: u8 = 1;

const TAG_REGISTER: u8 = 1;
const TAG_ASSIGN: u8 = 2;
const TAG_HEARTBEAT: u8 = 3;
const TAG_DRAIN: u8 = 4;
const TAG_STATS: u8 = 5;

/// Longest string any control message may carry.
const MAX_STR: usize = 4096;
/// Longest whole frame [`read_msg`] accepts.
pub const MAX_FRAME: usize = 64 * 1024;

/// A control-plane message between a node agent and the controller.
#[derive(Debug, Clone, PartialEq)]
pub enum CtlMsg {
    /// Agent → controller, first frame after connect: the node's
    /// identity and the device slots it contributes to the pool.
    Register { node_id: String, gpus: u32, device_bytes: u64 },
    /// Controller → agent: host one replica of `stage`, pulling inputs
    /// from `in_key`-prefixed store keys and pushing outputs to
    /// `out_key`-prefixed ones, with the payload store at `store`.
    Assign { stage: String, replica: u32, store: String, in_key: String, out_key: String },
    /// Agent → controller, periodic liveness + load signal.
    Heartbeat { node_id: String, seq: u64, inflight: u32 },
    /// Either direction.  Controller → agent: stop pulling new work,
    /// finish what is in flight, and shut down.  Agent → controller:
    /// the drain acknowledgement (echo), after which the agent exits.
    Drain { node_id: String },
    /// Agent → controller, sent right before the drain ack: per-edge
    /// transfer counters for the hops this agent executed.
    Stats { node_id: String, edges: Vec<EdgeTransferSnapshot> },
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF29CE484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

pub fn encode(msg: &CtlMsg) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    match msg {
        CtlMsg::Register { node_id, gpus, device_bytes } => {
            out.push(TAG_REGISTER);
            put_str(&mut out, node_id);
            out.extend_from_slice(&gpus.to_le_bytes());
            out.extend_from_slice(&device_bytes.to_le_bytes());
        }
        CtlMsg::Assign { stage, replica, store, in_key, out_key } => {
            out.push(TAG_ASSIGN);
            put_str(&mut out, stage);
            out.extend_from_slice(&replica.to_le_bytes());
            put_str(&mut out, store);
            put_str(&mut out, in_key);
            put_str(&mut out, out_key);
        }
        CtlMsg::Heartbeat { node_id, seq, inflight } => {
            out.push(TAG_HEARTBEAT);
            put_str(&mut out, node_id);
            out.extend_from_slice(&seq.to_le_bytes());
            out.extend_from_slice(&inflight.to_le_bytes());
        }
        CtlMsg::Drain { node_id } => {
            out.push(TAG_DRAIN);
            put_str(&mut out, node_id);
        }
        CtlMsg::Stats { node_id, edges } => {
            out.push(TAG_STATS);
            put_str(&mut out, node_id);
            out.extend_from_slice(&(edges.len() as u32).to_le_bytes());
            for e in edges {
                put_str(&mut out, &e.label);
                out.extend_from_slice(&e.bytes.to_le_bytes());
                out.extend_from_slice(&e.frames.to_le_bytes());
                out.extend_from_slice(&e.p50_ms.to_le_bytes());
                out.extend_from_slice(&e.p95_ms.to_le_bytes());
            }
        }
    }
    let sum = fnv1a(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

pub fn decode(bytes: &[u8]) -> Result<CtlMsg> {
    // Checksum first: a flipped byte anywhere is caught even when it
    // lands somewhere a structural check cannot see.
    if bytes.len() < 8 {
        bail!("ctl wire: frame too short ({} bytes)", bytes.len());
    }
    let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
    let declared = u64::from_le_bytes(sum_bytes.try_into().unwrap());
    if fnv1a(body) != declared {
        bail!("ctl wire: checksum mismatch (corrupt frame)");
    }
    let mut pos = 0usize;
    let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
        if *pos + n > body.len() {
            bail!("ctl wire: truncated at {} (+{n} > {})", *pos, body.len());
        }
        let s = &body[*pos..*pos + n];
        *pos += n;
        Ok(s)
    };
    let get_str = |pos: &mut usize| -> Result<String> {
        let len = u32::from_le_bytes(take(&mut *pos, 4)?.try_into().unwrap()) as usize;
        if len > MAX_STR {
            bail!("ctl wire: string of {len} bytes exceeds the {MAX_STR} cap");
        }
        String::from_utf8(take(&mut *pos, len)?.to_vec())
            .map_err(|_| anyhow::anyhow!("ctl wire: non-utf8 string"))
    };
    let magic = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
    if magic != MAGIC {
        bail!("ctl wire: bad magic {magic:#x}");
    }
    let version = take(&mut pos, 1)?[0];
    if version != VERSION {
        bail!("ctl wire: unsupported version {version}");
    }
    let tag = take(&mut pos, 1)?[0];
    let msg = match tag {
        TAG_REGISTER => {
            let node_id = get_str(&mut pos)?;
            let gpus = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
            let device_bytes = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
            CtlMsg::Register { node_id, gpus, device_bytes }
        }
        TAG_ASSIGN => {
            let stage = get_str(&mut pos)?;
            let replica = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
            let store = get_str(&mut pos)?;
            let in_key = get_str(&mut pos)?;
            let out_key = get_str(&mut pos)?;
            CtlMsg::Assign { stage, replica, store, in_key, out_key }
        }
        TAG_HEARTBEAT => {
            let node_id = get_str(&mut pos)?;
            let seq = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
            let inflight = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
            CtlMsg::Heartbeat { node_id, seq, inflight }
        }
        TAG_DRAIN => CtlMsg::Drain { node_id: get_str(&mut pos)? },
        TAG_STATS => {
            let node_id = get_str(&mut pos)?;
            let n = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            // Bound by the frame size before allocating (a corrupt count
            // must not OOM; each entry is at least 4 bytes of label len).
            if n > body.len() - pos {
                bail!("ctl wire: {n} edge stats cannot fit the remaining frame");
            }
            let mut edges = Vec::with_capacity(n);
            for _ in 0..n {
                let label = get_str(&mut pos)?;
                let bytes_moved = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
                let frames = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
                let p50_ms = f64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
                let p95_ms = f64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
                edges.push(EdgeTransferSnapshot {
                    label,
                    bytes: bytes_moved,
                    frames,
                    p50_ms,
                    p95_ms,
                });
            }
            CtlMsg::Stats { node_id, edges }
        }
        other => bail!("ctl wire: unknown tag {other}"),
    };
    if pos != body.len() {
        bail!("ctl wire: {} trailing bytes after payload", body.len() - pos);
    }
    Ok(msg)
}

/// Write one length-prefixed frame to a stream.
pub fn write_msg(w: &mut impl Write, msg: &CtlMsg) -> Result<()> {
    let frame = encode(msg);
    w.write_all(&(frame.len() as u32).to_le_bytes())?;
    w.write_all(&frame)?;
    w.flush()?;
    Ok(())
}

/// Marker carried in [`read_msg`]'s error when the socket read timed
/// out.  The vendored `anyhow` keeps message strings only (no
/// downcasting), so liveness code asks [`is_timeout`] instead of
/// inspecting an [`std::io::Error`] it can no longer reach.
const TIMEOUT_MARK: &str = "ctl wire: silent peer (read timed out)";

/// Whether an error from [`read_msg`] was a read timeout — a silent
/// peer — rather than a hangup or a corrupt frame.
pub fn is_timeout(e: &anyhow::Error) -> bool {
    e.chain().any(|m| m.contains(TIMEOUT_MARK))
}

fn read_exact_classified(r: &mut impl Read, buf: &mut [u8]) -> Result<()> {
    use std::io::ErrorKind;
    r.read_exact(buf).map_err(|e| {
        if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) {
            anyhow::anyhow!("{TIMEOUT_MARK}")
        } else {
            e.into()
        }
    })
}

/// Read one length-prefixed frame from a stream.
pub fn read_msg(r: &mut impl Read) -> Result<CtlMsg> {
    let mut len4 = [0u8; 4];
    read_exact_classified(r, &mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_FRAME {
        bail!("ctl wire: frame of {len} bytes exceeds the {MAX_FRAME} cap");
    }
    let mut frame = vec![0u8; len];
    read_exact_classified(r, &mut frame)?;
    decode(&frame)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;
    use crate::util::Prng;

    fn rand_str(rng: &mut Prng, max: usize) -> String {
        (0..rng.range(0, max)).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
    }

    fn sample(rng: &mut Prng) -> CtlMsg {
        match rng.below(5) {
            0 => CtlMsg::Register {
                node_id: rand_str(rng, 16),
                gpus: rng.below(16) as u32,
                device_bytes: rng.next_u64(),
            },
            1 => CtlMsg::Assign {
                stage: rand_str(rng, 16),
                replica: rng.below(8) as u32,
                store: rand_str(rng, 24),
                in_key: rand_str(rng, 24),
                out_key: rand_str(rng, 24),
            },
            2 => CtlMsg::Heartbeat {
                node_id: rand_str(rng, 16),
                seq: rng.next_u64(),
                inflight: rng.below(1000) as u32,
            },
            3 => CtlMsg::Drain { node_id: rand_str(rng, 16) },
            _ => CtlMsg::Stats {
                node_id: rand_str(rng, 16),
                edges: (0..rng.range(0, 4))
                    .map(|_| EdgeTransferSnapshot {
                        label: rand_str(rng, 24),
                        bytes: rng.next_u64(),
                        frames: rng.next_u64(),
                        p50_ms: rng.f64() * 100.0,
                        p95_ms: rng.f64() * 100.0,
                    })
                    .collect(),
            },
        }
    }

    #[test]
    fn prop_ctl_frame_roundtrips() {
        quick("ctl_wire_roundtrip", |rng| {
            let msg = sample(rng);
            let got = decode(&encode(&msg)).unwrap();
            assert_eq!(got, msg);
        });
    }

    #[test]
    fn ctl_frame_rejects_every_truncation() {
        let mut rng = Prng::new(13);
        for _ in 0..5 {
            let bytes = encode(&sample(&mut rng));
            // Every proper prefix must decode to an error, never a panic.
            for cut in 0..bytes.len() {
                assert!(decode(&bytes[..cut]).is_err(), "prefix of {cut} bytes decoded");
            }
            assert!(decode(&bytes).is_ok());
        }
    }

    #[test]
    fn prop_ctl_frame_rejects_bit_flips() {
        // The trailing checksum makes ANY single-byte corruption — tag,
        // strings, counters — a decode error.
        quick("ctl_wire_corruption", |rng| {
            let msg = sample(rng);
            let mut bytes = encode(&msg);
            let i = rng.range(0, bytes.len() - 1);
            let flip = (rng.below(255) + 1) as u8;
            bytes[i] ^= flip;
            assert!(decode(&bytes).is_err(), "flip at byte {i} slipped through");
        });
    }

    #[test]
    fn ctl_frame_rejects_wrong_magic_version_and_tag() {
        let msg = CtlMsg::Drain { node_id: "n0".into() };
        // Wrong magic, checksum recomputed so only the magic check fires.
        let mut bytes = encode(&msg);
        bytes[0] ^= 0xFF;
        let body_len = bytes.len() - 8;
        let sum = fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert!(decode(&bytes).is_err());
        // Unsupported version.
        let mut bytes = encode(&msg);
        bytes[4] = 99;
        let sum = fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert!(decode(&bytes).is_err());
        // Unknown tag.
        let mut bytes = encode(&msg);
        bytes[5] = 200;
        let sum = fnv1a(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn stream_framing_roundtrips_and_bounds_length() {
        let msgs = vec![
            CtlMsg::Register { node_id: "n0".into(), gpus: 2, device_bytes: 1 << 20 },
            CtlMsg::Heartbeat { node_id: "n0".into(), seq: 7, inflight: 3 },
            CtlMsg::Drain { node_id: "n0".into() },
        ];
        let mut buf: Vec<u8> = Vec::new();
        for m in &msgs {
            write_msg(&mut buf, m).unwrap();
        }
        let mut r = &buf[..];
        for m in &msgs {
            assert_eq!(&read_msg(&mut r).unwrap(), m);
        }
        // A corrupt length prefix is rejected before allocation.
        let huge = (MAX_FRAME as u32 + 1).to_le_bytes();
        assert!(read_msg(&mut &huge[..]).is_err());
    }
}
