//! The controller: owns the cluster control plane for one run.
//!
//! [`run_cluster_trace`] is the whole lifecycle in one call, used by the
//! two-process CI smoke and the loopback tests:
//!
//! 1. spawn the payload store (the data-plane rendezvous);
//! 2. connect to every node agent, collect `Register` frames into
//!    [`crate::config::NodeSpec`]s;
//! 3. run the placement engine ([`crate::cluster::placement::place`])
//!    over the registered capacity, then `Assign` each stage replica to
//!    its node with chained store-key streams;
//! 4. drive the trace: put request frames into the first stage's
//!    stream, collect them from the last stage's, then flush a
//!    zero-length sentinel through the chain;
//! 5. `Drain` every agent, harvest its `Stats` (per-edge transfer
//!    counters) and drain ack, and report.
//!
//! Liveness: agents heartbeat every `transport.heartbeat_s` and the
//! controller reads under `transport.read_timeout_s`, so a node that
//! dies mid-run — silently or with a hangup — surfaces as a structured
//! error naming the node, and the run aborts instead of hanging.  The
//! controller heartbeats back on the same cadence so agents get the
//! symmetric guarantee.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::config::{NodeSpec, PlacementPolicy, TransportConfig};
use crate::connector::tcp::{MooncakeStore, StoreClient};
use crate::connector::EdgeTransferSnapshot;

use super::placement::{place, ClusterPlan, EdgeDemand, StageDemand};
use super::wire::{read_msg, write_msg, CtlMsg};

/// Controller-side knobs for one cluster run.
#[derive(Debug, Clone)]
pub struct ControllerOptions {
    pub transport: TransportConfig,
    pub placement: PlacementPolicy,
    /// Per-replica weight bytes demanded from a node for each stage.
    pub stage_bytes: usize,
}

impl Default for ControllerOptions {
    fn default() -> Self {
        Self {
            transport: TransportConfig::default(),
            placement: PlacementPolicy::TransferAware,
            stage_bytes: 1 << 20,
        }
    }
}

/// What one cluster run did.
#[derive(Debug, Clone)]
pub struct ControllerReport {
    /// Node ids, in registration order.
    pub nodes: Vec<String>,
    pub plan: ClusterPlan,
    /// Requests that made it through the whole chain intact.
    pub completed: usize,
    /// Per-edge transfer counters harvested from the agents' `Stats`.
    pub edges: Vec<EdgeTransferSnapshot>,
    /// Heartbeats received across all agents.
    pub heartbeats: u64,
}

struct AgentConn {
    node_id: String,
    writer: Arc<Mutex<TcpStream>>,
    reader: thread::JoinHandle<Result<(Vec<EdgeTransferSnapshot>, u64)>>,
}

/// Run a stage chain over a set of node agents, driving `payloads`
/// through it end to end.  Each stage runs one replica, homed by the
/// placement engine over the agents' registered capacity.
pub fn run_cluster_trace(
    agent_addrs: &[String],
    stages: &[&str],
    payloads: &[Vec<u8>],
    opts: &ControllerOptions,
) -> Result<ControllerReport> {
    if agent_addrs.is_empty() || stages.is_empty() {
        bail!("controller: need at least one agent and one stage");
    }
    let store = MooncakeStore::spawn_with("127.0.0.1:0", &opts.transport)?;
    let dead: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
    let done = Arc::new(AtomicBool::new(false));

    // Connect + register every agent.
    let mut nodes = Vec::with_capacity(agent_addrs.len());
    let mut conns: Vec<AgentConn> = Vec::with_capacity(agent_addrs.len());
    for addr in agent_addrs {
        let stream = TcpStream::connect(addr).with_context(|| format!("controller -> agent {addr}"))?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(Duration::from_secs_f64(opts.transport.read_timeout_s)))?;
        let mut reader = stream.try_clone()?;
        let node_id = match read_msg(&mut reader)? {
            CtlMsg::Register { node_id, gpus, device_bytes } => {
                nodes.push(NodeSpec { id: node_id.clone(), gpus: gpus as usize, device_bytes: device_bytes as usize });
                node_id
            }
            other => bail!("agent {addr}: expected Register, got {other:?}"),
        };
        // Reader thread: heartbeats reset the read timeout implicitly;
        // silence or a hangup before the drain ack marks the node dead.
        let reader_handle = {
            let (node_id, dead, done) = (node_id.clone(), Arc::clone(&dead), Arc::clone(&done));
            thread::spawn(move || -> Result<(Vec<EdgeTransferSnapshot>, u64)> {
                let mut beats = 0u64;
                let mut edges = Vec::new();
                loop {
                    match read_msg(&mut reader) {
                        Ok(CtlMsg::Heartbeat { .. }) => beats += 1,
                        Ok(CtlMsg::Stats { edges: e, .. }) => edges = e,
                        Ok(CtlMsg::Drain { .. }) => return Ok((edges, beats)),
                        Ok(other) => {
                            let msg = format!("node `{node_id}`: unexpected {other:?}");
                            dead.lock().unwrap().get_or_insert(msg.clone());
                            bail!(msg);
                        }
                        Err(e) => {
                            let msg = if super::wire::is_timeout(&e) {
                                format!("node `{node_id}` dead: no heartbeat within the read timeout")
                            } else {
                                format!("node `{node_id}` hung up mid-run: {e:#}")
                            };
                            if !done.load(Ordering::Relaxed) {
                                dead.lock().unwrap().get_or_insert(msg.clone());
                            }
                            bail!(msg);
                        }
                    }
                }
            })
        };
        conns.push(AgentConn {
            node_id,
            writer: Arc::new(Mutex::new(stream)),
            reader: reader_handle,
        });
    }

    // Place the chain over the registered capacity.  Edge weight = mean
    // payload size, which is what actually moves per request.
    let mean_bytes = if payloads.is_empty() {
        0.0
    } else {
        payloads.iter().map(|p| p.len()).sum::<usize>() as f64 / payloads.len() as f64
    };
    let demands: Vec<StageDemand> = stages
        .iter()
        .map(|s| StageDemand {
            stage: s.to_string(),
            replicas: 1,
            tp: 1,
            bytes: opts.stage_bytes,
            compute_milli: crate::gpu_share::DEVICE_MILLI,
        })
        .collect();
    let edge_demands: Vec<EdgeDemand> = stages
        .windows(2)
        .map(|w| EdgeDemand { from: w[0].to_string(), to: w[1].to_string(), bytes_per_request: mean_bytes })
        .collect();
    let plan = place(&nodes, &demands, &edge_demands, opts.placement)?;

    // Assign each stage replica to its node, chaining streams: stage i
    // pulls from `e{i}` and pushes to `e{i+1}`.
    for (i, stage) in stages.iter().enumerate() {
        let node = plan.node_of(stage, 0).expect("placed above");
        write_msg(
            &mut *conns[node].writer.lock().unwrap(),
            &CtlMsg::Assign {
                stage: stage.to_string(),
                replica: 0,
                store: store.addr().to_string(),
                in_key: format!("e{i}"),
                out_key: format!("e{}", i + 1),
            },
        )?;
    }

    // Controller-side heartbeats (agents read under the same timeout).
    let beats_stop = Arc::new(AtomicBool::new(false));
    let beats_handle = {
        let writers: Vec<_> = conns.iter().map(|c| Arc::clone(&c.writer)).collect();
        let stop = Arc::clone(&beats_stop);
        let period = Duration::from_secs_f64(opts.transport.heartbeat_s);
        thread::spawn(move || {
            let mut seq = 0u64;
            while !stop.load(Ordering::Relaxed) {
                thread::sleep(period);
                for w in &writers {
                    let msg = CtlMsg::Heartbeat { node_id: "controller".into(), seq, inflight: 0 };
                    let _ = write_msg(&mut *w.lock().unwrap(), &msg);
                }
                seq += 1;
            }
        })
    };

    // Drive the trace on a thread so the main loop can watch liveness:
    // put every frame plus the sentinel, then take the chain's output.
    let (drive_tx, drive_rx) = mpsc::channel::<Result<usize>>();
    let driver = {
        let (store_addr, transport) = (store.addr().to_string(), opts.transport);
        let payloads = payloads.to_vec();
        let last = stages.len();
        thread::spawn(move || {
            let run = || -> Result<usize> {
                let mut cli = StoreClient::connect_with(&store_addr, &transport, "controller")?;
                for (i, p) in payloads.iter().enumerate() {
                    cli.put(&format!("e0:{i}"), p)?;
                }
                cli.put(&format!("e0:{}", payloads.len()), &[])?;
                let mut completed = 0usize;
                for (i, p) in payloads.iter().enumerate() {
                    let got = cli.get(&format!("e{last}:{i}"))?;
                    if &got == p {
                        completed += 1;
                    }
                }
                let sentinel = cli.get(&format!("e{last}:{}", payloads.len()))?;
                if !sentinel.is_empty() {
                    bail!("controller: end-of-stream sentinel came back non-empty");
                }
                Ok(completed)
            };
            drive_tx.send(run()).ok();
        })
    };

    // Watch the drive and the node liveness together: a dead node must
    // abort the run with its structured error, not hang the collector.
    let completed = loop {
        if let Some(msg) = dead.lock().unwrap().clone() {
            beats_stop.store(true, Ordering::Relaxed);
            bail!("cluster run aborted: {msg}");
        }
        match drive_rx.recv_timeout(Duration::from_millis(10)) {
            Ok(res) => break res?,
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => bail!("controller: trace driver died"),
        }
    };
    driver.join().ok();
    done.store(true, Ordering::Relaxed);

    // Drain: every agent sends Stats then acks; readers return both.
    for c in &conns {
        write_msg(&mut *c.writer.lock().unwrap(), &CtlMsg::Drain { node_id: c.node_id.clone() })?;
    }
    beats_stop.store(true, Ordering::Relaxed);
    let mut edges = Vec::new();
    let mut heartbeats = 0u64;
    for c in conns {
        let node_id = c.node_id;
        match c.reader.join() {
            Ok(Ok((mut e, beats))) => {
                for s in &mut e {
                    s.label = format!("{node_id}/{}", s.label);
                }
                edges.extend(e);
                heartbeats += beats;
            }
            Ok(Err(e)) => bail!("node `{node_id}` failed to drain cleanly: {e:#}"),
            Err(_) => bail!("node `{node_id}`: reader panicked"),
        }
    }
    beats_handle.join().ok();

    Ok(ControllerReport { nodes: nodes.into_iter().map(|n| n.id).collect(), plan, completed, edges, heartbeats })
}
