//! The node agent: one process per machine, hosting an assigned subset
//! of stage replicas (`omni-serve agent --node-id n0 --listen ...`).
//!
//! Lifecycle (one controller connection, frames from
//! [`crate::cluster::wire`]):
//!
//! 1. bind `--listen`, print the bound address, accept the controller;
//! 2. send `Register` (node identity + the device slots contributed);
//! 3. heartbeat every `transport.heartbeat_s`, reporting in-flight work;
//! 4. for each `Assign`, spawn a replica worker that pulls frames from
//!    its `in_key` stream on the payload store, executes the hop, and
//!    pushes to its `out_key` stream — chaining stages across processes
//!    through store keys, with per-hop transfer stats recorded;
//! 5. on `Drain`, join the workers, send `Stats` (per-edge counters)
//!    and the `Drain` ack, then exit.
//!
//! Liveness is symmetric: the controller heartbeats too, and the agent
//! reads its control stream under `transport.read_timeout_s` — a
//! controller that dies mid-run surfaces as a structured error naming
//! the silent peer, never a hang (same contract as the store clients in
//! [`crate::connector::tcp`]).
//!
//! Worker execution: a replica worker runs the stage's *transfer loop* —
//! take a frame, stamp it through, hand it downstream.  Engine compute
//! requires model artifacts, which the artifact-free CI smoke (and the
//! loopback tests) do not ship, so the hop is a relay: bytes in, bytes
//! out, end-of-stream on a zero-length sentinel frame that is forwarded
//! before the worker exits (so downstream workers and the controller's
//! collector terminate in order).

use std::io::{self, Write as _};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::TransportConfig;
use crate::connector::tcp::StoreClient;
use crate::connector::{EdgeTransferSnapshot, EdgeTransferStats};

use super::wire::{read_msg, write_msg, CtlMsg};

/// Everything `omni-serve agent` needs to come up.
#[derive(Debug, Clone)]
pub struct AgentOptions {
    pub node_id: String,
    /// Bind address for the control plane, e.g. `127.0.0.1:0`.
    pub listen: String,
    /// Device slots this node contributes to the controller's pool.
    pub gpus: u32,
    pub device_bytes: u64,
    pub transport: TransportConfig,
}

impl AgentOptions {
    pub fn new(node_id: &str, listen: &str) -> Self {
        Self {
            node_id: node_id.to_string(),
            listen: listen.to_string(),
            gpus: 2,
            device_bytes: crate::device::DEFAULT_DEVICE_BYTES as u64,
            transport: TransportConfig::default(),
        }
    }
}

/// What the agent did before draining.
#[derive(Debug, Clone)]
pub struct AgentReport {
    pub node_id: String,
    /// Replica workers hosted.
    pub assignments: usize,
    /// Frames moved across all hops (sentinels excluded).
    pub frames_moved: u64,
    /// Per-hop transfer counters, as sent to the controller.
    pub edges: Vec<EdgeTransferSnapshot>,
}

struct Worker {
    label: String,
    stats: Arc<EdgeTransferStats>,
    handle: thread::JoinHandle<Result<u64>>,
}

/// CLI entry: bind, announce the bound address on stdout (tests and
/// operators parse it), serve one controller session, exit.
pub fn run_agent(opts: &AgentOptions) -> Result<AgentReport> {
    let listener =
        TcpListener::bind(&opts.listen).with_context(|| format!("agent bind {}", opts.listen))?;
    println!("agent {} listening on {}", opts.node_id, listener.local_addr()?);
    io::stdout().flush().ok();
    let (stream, _) = listener.accept().context("agent accept")?;
    serve_controller(stream, opts)
}

/// In-process entry for tests: bind, hand the bound address back, serve
/// the controller session on a thread.
pub fn spawn_in_process(
    opts: AgentOptions,
) -> Result<(std::net::SocketAddr, thread::JoinHandle<Result<AgentReport>>)> {
    let listener =
        TcpListener::bind(&opts.listen).with_context(|| format!("agent bind {}", opts.listen))?;
    let addr = listener.local_addr()?;
    let handle = thread::spawn(move || {
        let (stream, _) = listener.accept().context("agent accept")?;
        serve_controller(stream, &opts)
    });
    Ok((addr, handle))
}

/// One controller session over an accepted control stream.
pub fn serve_controller(stream: TcpStream, opts: &AgentOptions) -> Result<AgentReport> {
    stream.set_nodelay(true).ok();
    // The controller heartbeats; silence past the read timeout means the
    // peer died and the agent must not hang on a dead control stream.
    stream
        .set_read_timeout(Some(Duration::from_secs_f64(opts.transport.read_timeout_s)))?;
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let mut reader = stream;

    write_msg(
        &mut *writer.lock().unwrap(),
        &CtlMsg::Register {
            node_id: opts.node_id.clone(),
            gpus: opts.gpus,
            device_bytes: opts.device_bytes,
        },
    )?;

    let inflight = Arc::new(AtomicU32::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let beats = {
        let writer = Arc::clone(&writer);
        let inflight = Arc::clone(&inflight);
        let stop = Arc::clone(&stop);
        let node_id = opts.node_id.clone();
        let period = Duration::from_secs_f64(opts.transport.heartbeat_s);
        thread::spawn(move || {
            let mut seq = 0u64;
            while !stop.load(Ordering::Relaxed) {
                thread::sleep(period);
                let msg = CtlMsg::Heartbeat {
                    node_id: node_id.clone(),
                    seq,
                    inflight: inflight.load(Ordering::Relaxed),
                };
                if write_msg(&mut *writer.lock().unwrap(), &msg).is_err() {
                    break; // controller gone; the read loop reports it
                }
                seq += 1;
            }
        })
    };

    let mut workers: Vec<Worker> = Vec::new();
    let mut assignments = 0usize;
    let drain_result = loop {
        match read_msg(&mut reader) {
            Ok(CtlMsg::Assign { stage, replica, store, in_key, out_key }) => {
                assignments += 1;
                let label = format!("{stage}#{replica}");
                let stats = Arc::new(EdgeTransferStats::default());
                let handle = {
                    let (label, stats) = (label.clone(), Arc::clone(&stats));
                    let (transport, inflight) = (opts.transport, Arc::clone(&inflight));
                    thread::spawn(move || {
                        relay_worker(&store, &in_key, &out_key, &label, &transport, &stats, &inflight)
                    })
                };
                workers.push(Worker { label, stats, handle });
            }
            Ok(CtlMsg::Heartbeat { .. }) => {} // controller liveness; the timeout reset is implicit
            Ok(CtlMsg::Drain { .. }) => break Ok(()),
            Ok(other) => break Err(anyhow::anyhow!(
                "agent `{}`: unexpected control message {other:?}",
                opts.node_id
            )),
            Err(e) => {
                let timed_out = super::wire::is_timeout(&e);
                break Err(if timed_out {
                    anyhow::anyhow!(
                        "agent `{}`: controller dead (no heartbeat within the read timeout)",
                        opts.node_id
                    )
                } else {
                    e.context(format!("agent `{}`: control stream closed", opts.node_id))
                });
            }
        }
    };

    stop.store(true, Ordering::Relaxed);
    // Workers exit on their sentinel frames (the controller flushes the
    // pipeline before Drain); join them and roll up the hop counters.
    let mut frames_moved = 0u64;
    let mut edges = Vec::with_capacity(workers.len());
    let mut worker_errors = Vec::new();
    for w in workers {
        match w.handle.join() {
            Ok(Ok(frames)) => frames_moved += frames,
            Ok(Err(e)) => worker_errors.push(format!("{}: {e:#}", w.label)),
            Err(_) => worker_errors.push(format!("{}: worker panicked", w.label)),
        }
        let mut snap = w.stats.snapshot();
        snap.label = w.label;
        edges.push(snap);
    }
    beats.join().ok();

    drain_result?;
    if !worker_errors.is_empty() {
        bail!("agent `{}`: {} worker(s) failed: {}", opts.node_id, worker_errors.len(), worker_errors.join("; "));
    }
    // Report the hop counters, then ack the drain and exit.
    {
        let mut w = writer.lock().unwrap();
        write_msg(&mut *w, &CtlMsg::Stats { node_id: opts.node_id.clone(), edges: edges.clone() })?;
        write_msg(&mut *w, &CtlMsg::Drain { node_id: opts.node_id.clone() })?;
    }
    Ok(AgentReport { node_id: opts.node_id.clone(), assignments, frames_moved, edges })
}

/// One replica worker: pull `{in_key}:{seq}`, push `{out_key}:{seq}`,
/// stop after forwarding the zero-length end-of-stream sentinel.  Store
/// GETs are destructive takes, so consumed slots release themselves; a
/// dead store surfaces the connector's structured dead-peer error.
fn relay_worker(
    store: &str,
    in_key: &str,
    out_key: &str,
    label: &str,
    transport: &TransportConfig,
    stats: &EdgeTransferStats,
    inflight: &AtomicU32,
) -> Result<u64> {
    let mut cli = StoreClient::connect_with(store, transport, label)?;
    let mut seq = 0u64;
    let mut frames = 0u64;
    loop {
        let t0 = Instant::now();
        let val = cli.get(&format!("{in_key}:{seq}"))?;
        inflight.fetch_add(1, Ordering::Relaxed);
        let put = cli.put(&format!("{out_key}:{seq}"), &val);
        inflight.fetch_sub(1, Ordering::Relaxed);
        put?;
        stats.record_sent(val.len() as u64);
        stats.record_latency(t0.elapsed().as_secs_f64());
        if val.is_empty() {
            return Ok(frames); // sentinel forwarded downstream
        }
        frames += 1;
        seq += 1;
    }
}
