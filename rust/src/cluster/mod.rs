//! Multi-node disaggregation (paper §3.1: the stages of an any-to-any
//! pipeline need not share a machine, only a transport).
//!
//! The single-process serving path wires stages with in-proc channels,
//! shm rings, or the TCP payload store ([`crate::connector`]).  This
//! module adds the pieces that let those stages span processes and
//! machines:
//!
//! * [`wire`] — the `OCTL` control-plane frame set
//!   (register/assign/heartbeat/drain/stats), checksummed and
//!   truncation-safe like the data-plane `OKVH` frames;
//! * [`placement`] — the controller-side cluster allocator: replicas →
//!   nodes under per-device memory admission, with transfer-cost-aware
//!   co-location and a per-edge transport selection matrix
//!   (cross-node ⇒ TCP, heavy local ⇒ shm, light local ⇒ in-proc);
//! * [`agent`] — the per-machine node agent (`omni-serve agent`):
//!   registers its capacity, hosts assigned stage replicas, heartbeats,
//!   drains cleanly;
//! * [`controller`] — the run driver: registration, placement,
//!   assignment, trace driving, liveness watching, drain + per-edge
//!   transfer-stat harvest.
//!
//! The link-aware half of the story — why transfer-aware placement wins
//! — is modeled in [`crate::scheduler::sim`]'s cross-node simulation and
//! gated in CI by `omni-serve bench --trace cross-node`.

pub mod agent;
pub mod controller;
pub mod placement;
pub mod wire;

pub use agent::{run_agent, AgentOptions, AgentReport};
pub use controller::{run_cluster_trace, ControllerOptions, ControllerReport};
pub use placement::{place, ClusterPlan, EdgeDemand, EdgeRoute, ReplicaPlacement, StageDemand};
pub use wire::CtlMsg;
