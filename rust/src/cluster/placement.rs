//! Transfer-cost-aware replica → node placement (the controller half of
//! the cluster allocator).
//!
//! [`place`] promotes the single-pool packing of
//! [`crate::scheduler::allocator`] to a multi-node setting: every node
//! contributes a [`crate::device::DevicePool`] (so per-device memory
//! admission is enforced with the same atomic-rollback reservation the
//! single-node path uses), replicas pick devices *within* a node with the
//! same least-loaded [`pack_group`] policy, and the new decision — which
//! node — is made by a [`PlacementPolicy`]:
//!
//! * `TransferAware` co-locates each replica with the upstream replica of
//!   its heaviest in-edge (affinity routing pairs replica `r` with
//!   upstream replica `r % m`), falling back to the node with the fewest
//!   replicas when the preferred node is out of memory.  The effect on a
//!   prefill→decode→vocoder chain is exactly the paper's layout: the
//!   KV-heavy prefill→decode hop stays node-local while the byte-light
//!   talker/vocoder hops are the ones allowed to cross nodes.
//! * `RoundRobin` is the naive baseline: next node with capacity,
//!   regardless of who talks to whom.
//!
//! Once replicas have homes, every edge gets a transport from the
//! selection matrix: any cross-node replica pair forces `Tcp`; a fully
//! node-local edge uses `Shm` when frames are large enough to be worth a
//! segment ([`SHM_MIN_BYTES`]) and the in-proc `Inline` channel below
//! that.

use anyhow::{bail, Result};

use crate::config::{ConnectorKind, NodeSpec, PlacementPolicy};
use crate::device::{DeviceId, DevicePool};
use crate::gpu_share::{MilliLedger, DEVICE_MILLI};
use crate::scheduler::allocator::{commit_group, pack_group};

/// Below this per-request frame size a node-local edge sticks with the
/// in-proc channel; at or above it the shared-memory ring pays off.
pub const SHM_MIN_BYTES: f64 = (64 * 1024) as f64;

/// What one stage asks of the cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct StageDemand {
    pub stage: String,
    pub replicas: usize,
    /// Tensor-parallel degree: devices per replica, all on one node.
    pub tp: usize,
    /// Per-replica weight bytes, sharded evenly across its TP group.
    pub bytes: usize,
    /// Per-replica compute share in milli-GPUs
    /// ([`crate::gpu_share::DEVICE_MILLI`] = a whole device).  Fractional
    /// single-device replicas pack into spare slivers of already-carved
    /// devices before claiming fresh ones.
    pub compute_milli: u32,
}

/// What one edge moves per request (drives transport selection and the
/// transfer-aware co-location).
#[derive(Debug, Clone, PartialEq)]
pub struct EdgeDemand {
    pub from: String,
    pub to: String,
    pub bytes_per_request: f64,
}

/// One replica's home: a node and a device group within it.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaPlacement {
    pub stage: String,
    pub replica: usize,
    /// Index into the `nodes` slice given to [`place`].
    pub node: usize,
    pub devices: Vec<DeviceId>,
}

/// An edge's resolved transport, with the replica-pair census that chose
/// it (affinity routing pairs request `id` with producer `id % m` and
/// consumer `id % n`, so the pair distribution cycles over `lcm(m, n)`).
#[derive(Debug, Clone, PartialEq)]
pub struct EdgeRoute {
    pub from: String,
    pub to: String,
    pub connector: ConnectorKind,
    pub cross_pairs: usize,
    pub local_pairs: usize,
}

/// A full cluster placement: every replica homed, every edge routed.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterPlan {
    pub placements: Vec<ReplicaPlacement>,
    pub routes: Vec<EdgeRoute>,
}

impl ClusterPlan {
    /// Node hosting replica `replica` of `stage`.
    pub fn node_of(&self, stage: &str, replica: usize) -> Option<usize> {
        self.placements
            .iter()
            .find(|p| p.stage == stage && p.replica == replica)
            .map(|p| p.node)
    }

    pub fn route(&self, from: &str, to: &str) -> Option<&EdgeRoute> {
        self.routes.iter().find(|r| r.from == from && r.to == to)
    }

    /// Replicas homed on `node`.
    pub fn replicas_on(&self, node: usize) -> usize {
        self.placements.iter().filter(|p| p.node == node).count()
    }

    /// Communicating replica pairs that cross a node boundary, over all
    /// edges — the quantity transfer-aware placement minimizes.
    pub fn cross_pairs(&self) -> usize {
        self.routes.iter().map(|r| r.cross_pairs).sum()
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Assign every stage replica a node + device group and every edge a
/// transport.  Fails (never panics) when a replica fits on no node,
/// naming the replica and the budgets that rejected it.
pub fn place(
    nodes: &[NodeSpec],
    stages: &[StageDemand],
    edges: &[EdgeDemand],
    policy: PlacementPolicy,
) -> Result<ClusterPlan> {
    if nodes.is_empty() {
        bail!("placement: no nodes registered");
    }
    for e in edges {
        for end in [&e.from, &e.to] {
            if !stages.iter().any(|s| &s.stage == end) {
                bail!("placement: edge `{}->{}` references unknown stage `{end}`", e.from, e.to);
            }
        }
    }
    let pools: Vec<DevicePool> =
        nodes.iter().map(|n| DevicePool::new(n.gpus, n.device_bytes)).collect();
    let mut node_load: Vec<Vec<usize>> = nodes.iter().map(|n| vec![0usize; n.gpus]).collect();
    let mut node_milli: Vec<MilliLedger> =
        nodes.iter().map(|n| MilliLedger::new(n.gpus)).collect();
    let mut placements: Vec<ReplicaPlacement> = Vec::new();
    // Reservations are held for the duration of placement so later
    // replicas see earlier ones' memory (the pools are dropped with the
    // function; the plan itself is the durable output).
    let mut holds = Vec::new();
    let mut rr = 0usize;

    for s in stages {
        if s.replicas == 0 || s.tp == 0 {
            bail!("placement: stage `{}` demands {} replicas x tp {}", s.stage, s.replicas, s.tp);
        }
        if s.compute_milli == 0 || s.compute_milli > DEVICE_MILLI {
            bail!(
                "placement: stage `{}` compute_milli {} outside 1..={DEVICE_MILLI}",
                s.stage,
                s.compute_milli
            );
        }
        let frac_demand = s.tp == 1 && s.compute_milli < DEVICE_MILLI;
        // The heaviest in-edge decides who this stage wants to sit with.
        let heaviest_in = edges
            .iter()
            .filter(|e| e.to == s.stage)
            .max_by(|a, b| a.bytes_per_request.total_cmp(&b.bytes_per_request));
        for r in 0..s.replicas {
            let mut try_node = |ni: usize,
                                node_load: &mut Vec<Vec<usize>>,
                                node_milli: &mut Vec<MilliLedger>,
                                holds: &mut Vec<_>|
             -> Option<Vec<DeviceId>> {
                if nodes[ni].gpus < s.tp {
                    return None;
                }
                // Fraction-first within the node: a fractional replica
                // slots into spare milli on an already-carved device
                // before least-loaded packing claims a fresh one.
                let group = match node_milli[ni].pack(s.compute_milli) {
                    Some(d) if frac_demand => vec![DeviceId(d)],
                    _ => pack_group(&node_load[ni], s.tp),
                };
                match pools[ni].reserve_tp(&group, s.bytes, &format!("{}#{r}", s.stage)) {
                    Ok(res) => {
                        holds.extend(res);
                        commit_group(&mut node_load[ni], &group);
                        for d in &group {
                            node_milli[ni].commit(d.0, s.compute_milli);
                        }
                        Some(group)
                    }
                    Err(_) => None,
                }
            };
            let chosen = match policy {
                PlacementPolicy::TransferAware => {
                    // Preferred: the node of the upstream replica this one
                    // will exchange the most bytes with.
                    let preferred = heaviest_in.and_then(|e| {
                        let m = stages.iter().find(|u| u.stage == e.from)?.replicas;
                        placements
                            .iter()
                            .find(|p| p.stage == e.from && p.replica == r % m)
                            .map(|p| p.node)
                    });
                    let mut order: Vec<usize> = (0..nodes.len()).collect();
                    // Fallback preference: for fractional demands, nodes
                    // holding a partially-carved device with room come
                    // first (slot packing per node); then fewest replicas,
                    // index tie-break (mirrors pack_group's device policy).
                    order.sort_by_key(|&ni| {
                        let sliver = frac_demand
                            && (0..nodes[ni].gpus).any(|d| {
                                let u = node_milli[ni].used(d);
                                u > 0 && node_milli[ni].fits(d, s.compute_milli)
                            });
                        (!sliver, placements.iter().filter(|p| p.node == ni).count(), ni)
                    });
                    if let Some(p) = preferred {
                        order.retain(|&ni| ni != p);
                        order.insert(0, p);
                    }
                    order.into_iter().find_map(|ni| {
                        try_node(ni, &mut node_load, &mut node_milli, &mut holds)
                            .map(|g| (ni, g))
                    })
                }
                PlacementPolicy::RoundRobin => {
                    let n = nodes.len();
                    (0..n).find_map(|attempt| {
                        let ni = (rr + attempt) % n;
                        try_node(ni, &mut node_load, &mut node_milli, &mut holds).map(|g| {
                            rr = ni + 1;
                            (ni, g)
                        })
                    })
                }
            };
            match chosen {
                Some((node, devices)) => {
                    placements.push(ReplicaPlacement { stage: s.stage.clone(), replica: r, node, devices });
                }
                None => bail!(
                    "placement: `{}` replica {r} (tp {}, {} bytes) fits on no node \
                     ({} nodes, budgets {:?})",
                    s.stage,
                    s.tp,
                    s.bytes,
                    nodes.len(),
                    nodes.iter().map(|n| (n.gpus, n.device_bytes)).collect::<Vec<_>>()
                ),
            }
        }
    }

    // Transport selection per edge, from the replica-pair census.
    let mut routes = Vec::with_capacity(edges.len());
    for e in edges {
        let m = stages.iter().find(|s| s.stage == e.from).unwrap().replicas;
        let n = stages.iter().find(|s| s.stage == e.to).unwrap().replicas;
        let cycle = m / gcd(m, n) * n;
        let mut cross_pairs = 0usize;
        for k in 0..cycle {
            let from_node = placements
                .iter()
                .find(|p| p.stage == e.from && p.replica == k % m)
                .map(|p| p.node);
            let to_node = placements
                .iter()
                .find(|p| p.stage == e.to && p.replica == k % n)
                .map(|p| p.node);
            if from_node != to_node {
                cross_pairs += 1;
            }
        }
        let connector = if cross_pairs > 0 {
            ConnectorKind::Tcp
        } else if e.bytes_per_request >= SHM_MIN_BYTES {
            ConnectorKind::Shm
        } else {
            ConnectorKind::Inline
        };
        routes.push(EdgeRoute {
            from: e.from.clone(),
            to: e.to.clone(),
            connector,
            cross_pairs,
            local_pairs: cycle - cross_pairs,
        });
    }
    drop(holds);
    Ok(ClusterPlan { placements, routes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;

    fn nodes(n: usize, gpus: usize, device_bytes: usize) -> Vec<NodeSpec> {
        (0..n)
            .map(|i| NodeSpec { id: format!("n{i}"), gpus, device_bytes })
            .collect()
    }

    /// The paper chain: heavy KV edge prefill→decode, light decode→voc.
    fn chain(bytes: usize) -> (Vec<StageDemand>, Vec<EdgeDemand>) {
        let demand = |name: &str| StageDemand {
            stage: name.into(),
            replicas: 2,
            tp: 1,
            bytes,
            compute_milli: DEVICE_MILLI,
        };
        let stages = vec![demand("prefill"), demand("decode"), demand("vocoder")];
        let edges = vec![
            EdgeDemand { from: "prefill".into(), to: "decode".into(), bytes_per_request: 16e6 },
            EdgeDemand { from: "decode".into(), to: "vocoder".into(), bytes_per_request: 8e3 },
        ];
        (stages, edges)
    }

    #[test]
    fn transfer_aware_colocates_the_heavy_edge() {
        // Nodes hold two replicas' weights each, so prefill+decode pairs
        // fill a node and the light vocoder hop is pushed cross-node —
        // exactly the layout the ISSUE asks for.
        let (stages, edges) = chain(80);
        let plan =
            place(&nodes(3, 2, 100), &stages, &edges, PlacementPolicy::TransferAware).unwrap();
        for r in 0..2 {
            assert_eq!(
                plan.node_of("prefill", r),
                plan.node_of("decode", r),
                "replica {r}: KV edge must stay node-local"
            );
        }
        let kv = plan.route("prefill", "decode").unwrap();
        assert_eq!(kv.cross_pairs, 0);
        assert_eq!(kv.connector, ConnectorKind::Shm, "heavy local edge takes the shm ring");
        let voc = plan.route("decode", "vocoder").unwrap();
        assert!(voc.cross_pairs > 0, "vocoder is the hop allowed to cross nodes");
        assert_eq!(voc.connector, ConnectorKind::Tcp);
    }

    #[test]
    fn round_robin_scatters_the_heavy_edge() {
        let (stages, edges) = chain(80);
        let plan = place(&nodes(3, 2, 100), &stages, &edges, PlacementPolicy::RoundRobin).unwrap();
        let kv = plan.route("prefill", "decode").unwrap();
        assert!(kv.cross_pairs > 0, "naive packing should misalign the KV edge");
        assert_eq!(kv.connector, ConnectorKind::Tcp);
        let ta =
            place(&nodes(3, 2, 100), &stages, &edges, PlacementPolicy::TransferAware).unwrap();
        assert!(
            ta.cross_pairs() < plan.cross_pairs(),
            "transfer-aware must cross fewer pairs ({} vs {})",
            ta.cross_pairs(),
            plan.cross_pairs()
        );
    }

    #[test]
    fn local_light_edge_stays_inline() {
        let stages = vec![
            StageDemand { stage: "a".into(), replicas: 1, tp: 1, bytes: 10, compute_milli: 1000 },
            StageDemand { stage: "b".into(), replicas: 1, tp: 1, bytes: 10, compute_milli: 1000 },
        ];
        let edges = vec![EdgeDemand { from: "a".into(), to: "b".into(), bytes_per_request: 100.0 }];
        let plan = place(&nodes(2, 2, 100), &stages, &edges, PlacementPolicy::TransferAware).unwrap();
        assert_eq!(plan.route("a", "b").unwrap().connector, ConnectorKind::Inline);
    }

    #[test]
    fn fractional_replicas_pack_into_node_slivers() {
        // Two 300-milli encoder replicas and a 300-milli vocoder replica
        // all fit a single device; the ledger packs them onto node 0's
        // carved device instead of scattering one per node.
        let stages = vec![
            StageDemand { stage: "enc".into(), replicas: 2, tp: 1, bytes: 10, compute_milli: 300 },
            StageDemand { stage: "voc".into(), replicas: 1, tp: 1, bytes: 10, compute_milli: 300 },
        ];
        let plan =
            place(&nodes(2, 1, 100), &stages, &[], PlacementPolicy::TransferAware).unwrap();
        assert_eq!(plan.node_of("enc", 0), Some(0));
        assert_eq!(plan.node_of("enc", 1), Some(0), "second fraction joins the sliver");
        assert_eq!(plan.node_of("voc", 0), Some(0), "third fraction still fits (900 milli)");
        assert_eq!(plan.replicas_on(1), 0, "node 1 stays free for whole replicas");
        // A whole-device demand then lands on the untouched node.
        let mut stages = stages;
        stages.push(StageDemand {
            stage: "thinker".into(),
            replicas: 1,
            tp: 1,
            bytes: 10,
            compute_milli: DEVICE_MILLI,
        });
        let plan =
            place(&nodes(2, 1, 100), &stages, &[], PlacementPolicy::TransferAware).unwrap();
        assert_eq!(plan.node_of("thinker", 0), Some(1));
    }

    #[test]
    fn infeasible_demand_bails_with_the_replica_named() {
        let stages = vec![StageDemand {
            stage: "big".into(),
            replicas: 1,
            tp: 1,
            bytes: 1000,
            compute_milli: 1000,
        }];
        let err = place(&nodes(2, 1, 100), &stages, &[], PlacementPolicy::TransferAware)
            .unwrap_err()
            .to_string();
        assert!(err.contains("`big` replica 0"), "got: {err}");
        // TP degree beyond any node's gpus also fails cleanly.
        let stages = vec![StageDemand {
            stage: "wide".into(),
            replicas: 1,
            tp: 4,
            bytes: 1,
            compute_milli: 1000,
        }];
        assert!(place(&nodes(2, 2, 100), &stages, &[], PlacementPolicy::RoundRobin).is_err());
        // compute_milli outside 1..=1000 is a demand error, not a panic.
        let stages = vec![StageDemand {
            stage: "zero".into(),
            replicas: 1,
            tp: 1,
            bytes: 1,
            compute_milli: 0,
        }];
        assert!(place(&nodes(1, 1, 100), &stages, &[], PlacementPolicy::RoundRobin).is_err());
    }

    #[test]
    fn unknown_edge_endpoint_is_rejected() {
        let stages = vec![StageDemand {
            stage: "a".into(),
            replicas: 1,
            tp: 1,
            bytes: 1,
            compute_milli: 1000,
        }];
        let edges = vec![EdgeDemand { from: "a".into(), to: "ghost".into(), bytes_per_request: 1.0 }];
        assert!(place(&nodes(1, 1, 100), &stages, &edges, PlacementPolicy::RoundRobin).is_err());
    }

    #[test]
    fn prop_placement_respects_every_budget() {
        // Satellite (f): random node capacities + stage demands.  Whenever
        // place() succeeds, no node exceeds its GPU or per-device memory
        // budget and every edge has a valid transport; when it fails, it
        // fails with an error, never a panic.
        quick("cluster_placement_budgets", |rng| {
            let nodes: Vec<NodeSpec> = (0..rng.range(1, 4))
                .map(|i| NodeSpec {
                    id: format!("n{i}"),
                    gpus: rng.range(1, 4),
                    device_bytes: rng.range(100, 10_000),
                })
                .collect();
            let stages: Vec<StageDemand> = (0..rng.range(1, 4))
                .map(|i| StageDemand {
                    stage: format!("s{i}"),
                    replicas: rng.range(1, 3),
                    tp: rng.range(1, 2),
                    bytes: rng.range(1, 12_000),
                    compute_milli: rng.range(50, 1000) as u32,
                })
                .collect();
            let edges: Vec<EdgeDemand> = stages
                .windows(2)
                .map(|w| EdgeDemand {
                    from: w[0].stage.clone(),
                    to: w[1].stage.clone(),
                    bytes_per_request: rng.f64() * 200_000.0,
                })
                .collect();
            let policy = if rng.bool(0.5) {
                PlacementPolicy::TransferAware
            } else {
                PlacementPolicy::RoundRobin
            };
            let Ok(plan) = place(&nodes, &stages, &edges, policy) else {
                return; // over-subscription is allowed to fail, not panic
            };
            // Every replica placed exactly once, on devices the node has.
            let mut usage: Vec<Vec<usize>> =
                nodes.iter().map(|n| vec![0usize; n.gpus]).collect();
            for s in &stages {
                for r in 0..s.replicas {
                    let hits: Vec<_> = plan
                        .placements
                        .iter()
                        .filter(|p| p.stage == s.stage && p.replica == r)
                        .collect();
                    assert_eq!(hits.len(), 1, "{} replica {r} placed {} times", s.stage, hits.len());
                    let p = hits[0];
                    assert_eq!(p.devices.len(), s.tp);
                    let mut seen = std::collections::HashSet::new();
                    for d in &p.devices {
                        assert!(d.0 < nodes[p.node].gpus, "device {} beyond node {}", d.0, p.node);
                        assert!(seen.insert(d.0), "device {} reused within a TP group", d.0);
                        usage[p.node][d.0] += s.bytes.div_ceil(s.tp);
                    }
                }
            }
            for (ni, node) in nodes.iter().enumerate() {
                for (di, &used) in usage[ni].iter().enumerate() {
                    assert!(
                        used <= node.device_bytes,
                        "node {ni} device {di}: {used} > budget {}",
                        node.device_bytes
                    );
                }
            }
            // Every edge routed with a transport consistent with the plan.
            assert_eq!(plan.routes.len(), edges.len());
            for (route, e) in plan.routes.iter().zip(&edges) {
                let expect = if route.cross_pairs > 0 {
                    ConnectorKind::Tcp
                } else if e.bytes_per_request >= SHM_MIN_BYTES {
                    ConnectorKind::Shm
                } else {
                    ConnectorKind::Inline
                };
                assert_eq!(route.connector, expect, "edge {}->{}", e.from, e.to);
                assert!(route.cross_pairs + route.local_pairs > 0);
            }
        });
    }
}
