//! KV-transfer subsystem (paper §3.4 — prefill/decode disaggregation).
//!
//! A prefill-role AR engine runs chunked prefill, samples the request's
//! first token, and then — instead of decoding in place — serializes the
//! sequence's whole KV-cache state into a [`KvHandoff`]:
//!
//! * the **resident KV rows** for every cached prompt position
//!   (`[L, 2, H, len, dh]` row-major, the payload);
//! * the **block-table accounting** ([`KvSeqExport`]): per-full-block
//!   prefix chain hashes, so the importing pool reuses already-resident
//!   prefix blocks (hash-based prefix sharing across the stage boundary)
//!   instead of allocating fresh ones;
//! * the **continuation state** a decode engine needs to pick the
//!   sequence up exactly where prefill left it: the first sampled token,
//!   its hidden row, the sampling parameters, and the sampler PRNG
//!   position — greedy *and* stochastic decoding reproduce the fused
//!   engine bit-for-bit.
//!
//! The handoff crosses the stage graph inside a normal
//! [`crate::engine::StageItem`] under the [`KV_TENSOR`] key, framed by
//! the dedicated wire format in [`crate::connector::wire`] (checksummed;
//! malformed frames error instead of panicking), so every connector kind
//! (inline / shm / tcp) transports it unchanged.  The `kv2decode`
//! transfer on the prefill→decode edge unpacks it into an
//! `EngineCmd::SubmitKv` for the decode engine.

use anyhow::{bail, Result};

use crate::connector::wire;
use crate::engine::SamplingParams;
use crate::kv_cache::KvSeqExport;
use crate::runtime::HostTensor;

/// `StageItem` tensor key under which an encoded handoff frame travels.
pub const KV_TENSOR: &str = "kv_handoff";

/// `StageItem` tensor key carrying the exported prompt's first
/// full-block chain hash (the request's prompt signature for
/// cache-aware routing, see [`crate::connector::router`]).  Packed as
/// two i32 words `[lo, hi]` because [`HostTensor`] has no u64 dtype.
/// Optional: prompts shorter than one block export no signature.
pub const KV_SIG_TENSOR: &str = "kv_sig";

/// Pack a prompt signature into its [`KV_SIG_TENSOR`] wire form.
pub fn sig_to_tensor(sig: u64) -> HostTensor {
    HostTensor::i32(vec![2], vec![sig as u32 as i32, (sig >> 32) as u32 as i32])
}

/// Recover a prompt signature from a [`KV_SIG_TENSOR`] tensor (`None`
/// for malformed shapes rather than an error: the hint is advisory).
pub fn sig_from_tensor(t: &HostTensor) -> Option<u64> {
    let v = t.as_i32().ok()?;
    if v.len() != 2 {
        return None;
    }
    Some((v[0] as u32 as u64) | ((v[1] as u32 as u64) << 32))
}

/// A sequence's complete KV-cache state in transit between a prefill
/// engine and a decode engine.
#[derive(Debug, Clone, PartialEq)]
pub struct KvHandoff {
    pub req_id: u64,
    /// Prompt tokens resident in the exported cache (positions `0..len`).
    pub len: usize,
    /// First decode token, sampled by the prefill engine from the last
    /// prompt position's logits.
    pub first_token: u32,
    /// Hidden row of the first token (`[d_model]`; empty when the
    /// exporting stage does not emit hiddens).
    pub hidden: Vec<f32>,
    pub sampling: SamplingParams,
    /// Sampler PRNG state *after* the first sample, so stochastic decode
    /// continues the exact stream the fused engine would have used.
    pub prng_state: u64,
    /// KV geometry (must match the importing engine's model).
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    /// Block-table accounting with prefix hashes (importer-side dedup).
    pub blocks: KvSeqExport,
    /// Resident KV rows, `[n_layers, 2, n_heads, len, d_head]` row-major.
    pub kv: Vec<f32>,
}

impl KvHandoff {
    /// Expected payload length for the declared geometry.
    pub fn expected_kv_floats(&self) -> usize {
        self.n_layers * 2 * self.n_heads * self.len * self.d_head
    }

    /// Structural validation (shared by the engine import path and the
    /// wire decoder): geometry, payload size, and block accounting must
    /// agree.
    pub fn check(&self) -> Result<()> {
        if self.kv.len() != self.expected_kv_floats() {
            bail!(
                "kv handoff req {}: payload {} floats, geometry [{}x2x{}x{}x{}] needs {}",
                self.req_id,
                self.kv.len(),
                self.n_layers,
                self.n_heads,
                self.len,
                self.d_head,
                self.expected_kv_floats()
            );
        }
        if self.blocks.len as usize != self.len {
            bail!(
                "kv handoff req {}: block accounting covers {} tokens, payload {}",
                self.req_id,
                self.blocks.len,
                self.len
            );
        }
        Ok(())
    }

    /// Pack the wire frame into a `StageItem`-transportable i32 tensor:
    /// element 0 is the frame byte length, the rest the frame bytes in
    /// little-endian 4-byte groups (zero-padded).
    pub fn to_tensor(&self) -> HostTensor {
        let bytes = wire::encode_kv(self);
        let words = bytes.len().div_ceil(4);
        let mut data = Vec::with_capacity(1 + words);
        data.push(bytes.len() as i32);
        for chunk in bytes.chunks(4) {
            let mut w = [0u8; 4];
            w[..chunk.len()].copy_from_slice(chunk);
            data.push(i32::from_le_bytes(w));
        }
        HostTensor::i32(vec![1 + words], data)
    }

    /// Unpack a tensor produced by [`Self::to_tensor`].
    pub fn from_tensor(t: &HostTensor) -> Result<Self> {
        let data = t.as_i32()?;
        let Some((&len_word, words)) = data.split_first() else {
            bail!("kv handoff tensor is empty");
        };
        let byte_len = len_word as usize;
        if len_word < 0 || byte_len > words.len() * 4 {
            bail!("kv handoff tensor: declared {byte_len} bytes, carries {}", words.len() * 4);
        }
        let mut bytes = Vec::with_capacity(words.len() * 4);
        for w in words {
            bytes.extend_from_slice(&w.to_le_bytes());
        }
        bytes.truncate(byte_len);
        wire::decode_kv(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompt_signature_roundtrips_through_its_tensor() {
        for sig in [0u64, 1, 0xDEAD_BEEF, u64::MAX, 0x8000_0000_0000_0001] {
            assert_eq!(sig_from_tensor(&sig_to_tensor(sig)), Some(sig));
        }
        // Malformed shapes degrade to "no hint", never an error.
        assert_eq!(sig_from_tensor(&HostTensor::i32(vec![3], vec![1, 2, 3])), None);
        assert_eq!(sig_from_tensor(&HostTensor::f32(vec![2], vec![1.0, 2.0])), None);
    }

    pub(crate) fn sample_handoff() -> KvHandoff {
        let (n_layers, n_heads, d_head, len) = (2usize, 3usize, 4usize, 5usize);
        let kv: Vec<f32> =
            (0..n_layers * 2 * n_heads * len * d_head).map(|i| i as f32 * 0.25 - 3.0).collect();
        KvHandoff {
            req_id: 42,
            len,
            first_token: 77,
            hidden: vec![0.5, -1.5, 2.0],
            sampling: SamplingParams {
                max_new_tokens: 12,
                temperature: 0.7,
                top_k: 5,
                ignore_eos: true,
                seed: 9,
            },
            prng_state: 0xDEAD_BEEF_CAFE_F00D,
            n_layers,
            n_heads,
            d_head,
            blocks: KvSeqExport {
                block_size: 2,
                len: len as u64,
                full_hashes: vec![Some(0xABCD), None],
            },
            kv,
        }
    }

    #[test]
    fn tensor_roundtrip() {
        let h = sample_handoff();
        h.check().unwrap();
        let t = h.to_tensor();
        let got = KvHandoff::from_tensor(&t).unwrap();
        assert_eq!(got, h);
    }

    #[test]
    fn tensor_rejects_garbage() {
        assert!(KvHandoff::from_tensor(&HostTensor::i32(vec![0], vec![])).is_err());
        // Declared length beyond the carried words.
        assert!(KvHandoff::from_tensor(&HostTensor::i32(vec![2], vec![100, 0])).is_err());
        // Wrong dtype.
        assert!(KvHandoff::from_tensor(&HostTensor::f32(vec![2], vec![0.0, 1.0])).is_err());
        // Well-formed carrier, corrupt frame inside.
        assert!(KvHandoff::from_tensor(&HostTensor::i32(vec![3], vec![8, 0, 0])).is_err());
    }

    #[test]
    fn check_catches_mismatched_geometry() {
        let mut h = sample_handoff();
        h.kv.pop();
        assert!(h.check().is_err());
        let mut h = sample_handoff();
        h.blocks.len = 99;
        assert!(h.check().is_err());
    }
}
