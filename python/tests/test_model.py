"""L2 stage-function correctness: shapes, KV-cache semantics, and
equivalence between the incremental (prefill+decode) path and a
one-shot full-attention reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs as C
from compile import layers as L
from compile import model as M

TINY = C.ArConfig("tiny", vocab=64, d_model=32, n_layers=2, n_heads=2,
                  d_head=16, d_ff=64, max_seq=64)
TINY_COND = C.ArConfig("tiny_cond", vocab=64, d_model=32, n_layers=2,
                       n_heads=2, d_head=16, d_ff=64, max_seq=64, cond_dim=24)


@pytest.fixture(scope="module")
def tiny_params():
    return L.ar_init(TINY, 0)


@pytest.fixture(scope="module")
def tiny_cond_params():
    return L.ar_init(TINY_COND, 1)


def _full_forward_ref(params, cfg, tokens):
    """One-shot causal forward over a full sequence (no cache): the oracle
    the incremental path must match.  tokens: [B, T]."""
    b, t = tokens.shape
    h, dh = cfg.n_heads, cfg.d_head
    x = params["embed"][tokens] + params["pos"][jnp.arange(t)][None]
    mask = jnp.tril(jnp.ones((t, t), bool))
    for l in range(cfg.n_layers):
        p = f"l{l:02d}."
        xn = L.rms_norm(x, params[p + "ln1"])
        q = jnp.einsum("btd,de->bte", xn, params[p + "wq"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        k = jnp.einsum("btd,de->bte", xn, params[p + "wk"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        v = jnp.einsum("btd,de->bte", xn, params[p + "wv"]).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhtd,bhsd->bhts", q, k) / np.sqrt(dh)
        att = jnp.where(mask[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhts,bhsd->bhtd", att, v).transpose(0, 2, 1, 3).reshape(b, t, -1)
        x = x + jnp.einsum("bte,ed->btd", o, params[p + "wo"])
        xn = L.rms_norm(x, params[p + "ln2"])
        x = x + jnp.einsum("btf,fd->btd", L.gelu(jnp.einsum("btd,df->btf", xn, params[p + "w1"])), params[p + "w2"])
    hidden = L.rms_norm(x, params["lnf"])
    return jnp.einsum("btd,dv->btv", hidden, params["lm_head"])


def test_decode_steps_match_full_forward(tiny_params):
    """Feeding tokens one-by-one through ar_decode_step must reproduce the
    one-shot causal forward logits at every position."""
    rng = np.random.default_rng(0)
    b, t = 2, 12
    tokens = jnp.asarray(rng.integers(0, TINY.vocab, (b, t)), jnp.int32)
    ref_logits = _full_forward_ref(tiny_params, TINY, tokens)

    kv = jnp.zeros(L.kv_shape(TINY, b), jnp.float32)
    length = jnp.zeros((b,), jnp.int32)
    for i in range(t):
        logits, hidden, kv = M.ar_decode_step(tiny_params, TINY, tokens[:, i], None, kv, length)
        length = length + 1
        np.testing.assert_allclose(logits, ref_logits[:, i], rtol=2e-4, atol=2e-4)


def test_prefill_chunks_match_full_forward(tiny_params):
    """Chunked prefill over C-sized chunks must reproduce the one-shot
    causal forward logits."""
    rng = np.random.default_rng(1)
    b, t, c = 2, 24, 8
    tokens = jnp.asarray(rng.integers(0, TINY.vocab, (b, t)), jnp.int32)
    ref_logits = _full_forward_ref(tiny_params, TINY, tokens)

    kv = jnp.zeros(L.kv_shape(TINY, b), jnp.float32)
    base = jnp.zeros((b,), jnp.int32)
    mm = jnp.zeros((b, c, TINY.d_model), jnp.float32)
    mask = jnp.zeros((b, c), jnp.float32)
    for i in range(0, t, c):
        logits, hidden, kv = M.ar_prefill_chunk(
            tiny_params, TINY, tokens[:, i:i + c], mm, mask, kv, base)
        base = base + c
        np.testing.assert_allclose(logits, ref_logits[:, i:i + c], rtol=2e-4, atol=2e-4)


def test_prefill_then_decode_consistent(tiny_params):
    """Prefill a prompt, then decode: logits must match the full forward."""
    rng = np.random.default_rng(2)
    b, t, c = 1, 8, 8
    tokens = jnp.asarray(rng.integers(0, TINY.vocab, (b, t + 1)), jnp.int32)
    ref_logits = _full_forward_ref(tiny_params, TINY, tokens)

    kv = jnp.zeros(L.kv_shape(TINY, b), jnp.float32)
    mm = jnp.zeros((b, c, TINY.d_model), jnp.float32)
    mask = jnp.zeros((b, c), jnp.float32)
    logits, _, kv = M.ar_prefill_chunk(tiny_params, TINY, tokens[:, :c], mm, mask, kv,
                                       jnp.zeros((b,), jnp.int32))
    np.testing.assert_allclose(logits[:, -1], ref_logits[:, c - 1], rtol=2e-4, atol=2e-4)
    logits2, _, kv = M.ar_decode_step(tiny_params, TINY, tokens[:, c], None, kv,
                                      jnp.full((b,), c, jnp.int32))
    np.testing.assert_allclose(logits2, ref_logits[:, c], rtol=2e-4, atol=2e-4)


def test_mm_embeds_replace_tokens(tiny_params):
    """Rows with mm_mask=1 must use the embedding stream: supplying the
    model's own token embedding as mm_embeds must equal the token path."""
    rng = np.random.default_rng(3)
    b, c = 2, 8
    tokens = jnp.asarray(rng.integers(0, TINY.vocab, (b, c)), jnp.int32)
    kv0 = jnp.zeros(L.kv_shape(TINY, b), jnp.float32)
    base = jnp.zeros((b,), jnp.int32)

    mm_zero = jnp.zeros((b, c, TINY.d_model), jnp.float32)
    l_tok, _, _ = M.ar_prefill_chunk(tiny_params, TINY, tokens, mm_zero,
                                     jnp.zeros((b, c)), kv0, base)
    mm_emb = tiny_params["embed"][tokens]
    junk = jnp.asarray(rng.integers(0, TINY.vocab, (b, c)), jnp.int32)
    l_mm, _, _ = M.ar_prefill_chunk(tiny_params, TINY, junk, mm_emb,
                                    jnp.ones((b, c)), kv0, base)
    np.testing.assert_allclose(l_tok, l_mm, rtol=2e-5, atol=2e-5)


def test_cond_stream_changes_output(tiny_cond_params):
    rng = np.random.default_rng(4)
    b = 2
    kv = jnp.zeros(L.kv_shape(TINY_COND, b), jnp.float32)
    token = jnp.asarray([1, 2], jnp.int32)
    length = jnp.zeros((b,), jnp.int32)
    cond0 = jnp.zeros((b, TINY_COND.cond_dim), jnp.float32)
    cond1 = jnp.asarray(rng.normal(size=(b, TINY_COND.cond_dim)), jnp.float32)
    l0, _, _ = M.ar_decode_step(tiny_cond_params, TINY_COND, token, cond0, kv, length)
    l1, _, _ = M.ar_decode_step(tiny_cond_params, TINY_COND, token, cond1, kv, length)
    assert not np.allclose(l0, l1)


def test_decode_scan_matches_stepwise(tiny_params):
    """ar_decode_scan greedy rollout == repeated ar_decode_step + argmax."""
    rng = np.random.default_rng(5)
    b, k = 2, 6
    kv = jnp.zeros(L.kv_shape(TINY, b), jnp.float32)
    length = jnp.zeros((b,), jnp.int32)
    token0 = jnp.asarray([3, 4], jnp.int32)
    active = jnp.ones((b,), jnp.float32)

    toks, hid, kv_s, len_s, act_s = M.ar_decode_scan(
        tiny_params, TINY, token0, None, kv, length, active,
        jnp.full((b,), TINY.eos_id, jnp.int32), n_steps=k)

    # step-by-step reference
    cur, kv_r, len_r = token0, kv, length
    out = []
    alive = np.ones(b, bool)
    for i in range(k):
        logits, _, kv_n = M.ar_decode_step(tiny_params, TINY, cur, None, kv_r, len_r)
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        emitted = np.where(alive, nxt, 0)
        out.append(emitted)
        kv_r = jnp.where(jnp.asarray(alive)[None, None, :, None, None, None], kv_n, kv_r)
        len_r = jnp.where(jnp.asarray(alive), len_r + 1, len_r)
        alive = alive & (nxt != TINY.eos_id)
        cur = jnp.asarray(emitted, jnp.int32)
    np.testing.assert_array_equal(np.asarray(toks), np.stack(out, axis=1))
    np.testing.assert_array_equal(np.asarray(len_s), np.asarray(len_r))


def test_decode_scan_freezes_after_eos(tiny_params):
    """Once a lane emits EOS its length must stop advancing."""
    b, k = 1, 8
    kv = jnp.zeros(L.kv_shape(TINY, b), jnp.float32)
    toks, _, _, len_f, act_f = M.ar_decode_scan(
        tiny_params, TINY, jnp.asarray([0], jnp.int32), None, kv,
        jnp.zeros((b,), jnp.int32), jnp.ones((b,), jnp.float32),
        jnp.full((b,), TINY.eos_id, jnp.int32), n_steps=k)
    toks = np.asarray(toks)[0]
    if (toks == TINY.eos_id).any():
        stop = int(np.argmax(toks == TINY.eos_id))
        assert (toks[stop + 1:] == 0).all()
        assert int(len_f[0]) == stop + 1


def test_inactive_lane_is_inert(tiny_params):
    """active=0 lanes emit 0 tokens and leave kv/length untouched."""
    b, k = 2, 4
    kv = jnp.zeros(L.kv_shape(TINY, b), jnp.float32)
    length = jnp.asarray([0, 5], jnp.int32)
    active = jnp.asarray([1.0, 0.0], jnp.float32)
    toks, _, kv_f, len_f, _ = M.ar_decode_scan(
        tiny_params, TINY, jnp.asarray([1, 1], jnp.int32), None, kv, length,
        active, jnp.full((b,), TINY.eos_id, jnp.int32), n_steps=k)
    assert (np.asarray(toks)[1] == 0).all()
    assert int(len_f[1]) == 5
    np.testing.assert_array_equal(np.asarray(kv_f[:, :, 1]), np.asarray(kv[:, :, 1]))


# ---------------------------------------------------------------------------
# DiT / vocoder / codec shapes & behaviours
# ---------------------------------------------------------------------------

VOC = C.DitConfig("voc_t", n_tokens=16, latent_dim=8, d_model=64, n_layers=2,
                  n_heads=2, d_ff=128, cond_dim=0, cond_tokens_dim=12)
IMG = C.DitConfig("img_t", n_tokens=16, latent_dim=8, d_model=64, n_layers=2,
                  n_heads=2, d_ff=128, cond_dim=24)


def test_dit_step_shapes_and_tmod():
    params = L.dit_init(IMG, 7)
    b = 2
    rng = np.random.default_rng(8)
    latent = jnp.asarray(rng.normal(size=(b, IMG.n_tokens, IMG.latent_dim)), jnp.float32)
    cond = jnp.asarray(rng.normal(size=(b, IMG.cond_dim)), jnp.float32)
    ct = jnp.zeros((b, IMG.n_tokens, 1), jnp.float32)
    t = jnp.asarray([0.5, 0.9], jnp.float32)
    g = jnp.ones((b,), jnp.float32)
    eps, t_mod = M.dit_step(params, IMG, latent, cond, ct, t, g)
    assert eps.shape == (b, IMG.n_tokens, IMG.latent_dim)
    assert t_mod.shape == (b, IMG.d_model)


def test_dit_cfg_scale_one_equals_cond_branch():
    """cfg_scale == 1 must equal the pure conditional branch."""
    params = L.dit_init(IMG, 9)
    rng = np.random.default_rng(9)
    b = 1
    latent = jnp.asarray(rng.normal(size=(b, IMG.n_tokens, IMG.latent_dim)), jnp.float32)
    cond = jnp.asarray(rng.normal(size=(b, IMG.cond_dim)), jnp.float32)
    ct = jnp.zeros((b, IMG.n_tokens, 1), jnp.float32)
    t = jnp.asarray([0.3], jnp.float32)
    eps1, t_mod = M.dit_step(params, IMG, latent, cond, ct, t, jnp.ones((b,)))
    # conditional branch computed directly
    x = jnp.einsum("bnl,ld->bnd", latent, params["in_proj"]) + params["pos"][None]
    tb = L.sinusoidal_embed(t, IMG.d_model)
    tb = jnp.dot(L.gelu(jnp.dot(tb, params["t_mlp1"])), params["t_mlp2"])
    tc = tb + jnp.dot(cond, params["cond_proj"])
    eps_c = M._dit_trunk(params, IMG, x, tc)
    np.testing.assert_allclose(eps1, eps_c, rtol=2e-4, atol=2e-4)


def test_dit_timestep_sensitivity():
    """t_mod must move between timesteps (TeaCache signal is non-trivial)."""
    params = L.dit_init(VOC, 10)
    b = 1
    latent = jnp.zeros((b, VOC.n_tokens, VOC.latent_dim), jnp.float32)
    cond = jnp.zeros((b, 1), jnp.float32)
    ct = jnp.zeros((b, VOC.n_tokens, VOC.cond_tokens_dim), jnp.float32)
    _, m1 = M.dit_step(params, VOC, latent, cond, ct, jnp.asarray([0.9]), jnp.ones((b,)))
    _, m2 = M.dit_step(params, VOC, latent, cond, ct, jnp.asarray([0.1]), jnp.ones((b,)))
    assert float(jnp.abs(m1 - m2).max()) > 1e-3


def test_cnn_vocoder_shape_and_range():
    cfg = C.CnnVocoderConfig("t", vocab=32, t_frames=8, d_embed=16,
                             channels=16, upsample=16)
    params = L.cnn_vocoder_init(cfg, 11)
    tokens = jnp.asarray(np.random.default_rng(0).integers(0, 32, (2, 8)), jnp.int32)
    wave = M.cnn_vocoder(params, cfg, tokens)
    assert wave.shape == (2, 8 * 16)
    assert float(jnp.abs(wave).max()) <= 1.0 + 1e-6


def test_patch_codec_roundtrip_shapes():
    cfg = C.PatchCodecConfig("t", patch_dim=16, t_max=8, d_model=32,
                             vocab=64, samples_per_patch=20)
    params = L.patch_codec_init(cfg, 12)
    feats = jnp.zeros((2, 8, 16), jnp.float32)
    emb = M.patch_encode(params, cfg, feats)
    assert emb.shape == (2, 8, 32)
    toks = jnp.zeros((2, 8), jnp.int32)
    patches = M.patch_decode(params, cfg, toks)
    assert patches.shape == (2, 8, 20)
    assert float(jnp.abs(patches).max()) <= 1.0 + 1e-6


def test_mm_encode_respects_mask():
    cfg = C.EncoderConfig("t", feat_dim=8, t_max=16, d_inner=32, n_layers=1,
                          n_heads=2, d_out=24)
    params = L.encoder_init(cfg, 13)
    rng = np.random.default_rng(14)
    feats = jnp.asarray(rng.normal(size=(1, 16, 8)), jnp.float32)
    mask = jnp.asarray([[1.0] * 4 + [0.0] * 12])
    out = M.mm_encode(params, cfg, feats, mask)
    assert out.shape == (1, 16, 24)
    np.testing.assert_array_equal(np.asarray(out[0, 4:]), 0.0)
