"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; every property asserts allclose
against ``compile.kernels.ref``.  This is the core correctness signal for
the compute hot path that the Rust runtime replays.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import dit_block as D
from compile.kernels import ref as R

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return dict(rtol=3e-5, atol=3e-5) if dtype == jnp.float32 else dict(rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@given(
    b=st.integers(1, 5),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([64, 128, 256]),
    dh=st.sampled_from([16, 32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_decode_attention_matches_ref(b, h, s, dh, dtype, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h, dh), dtype)
    k = _rand(rng, (b, h, s, dh), dtype)
    v = _rand(rng, (b, h, s, dh), dtype)
    lengths = jnp.asarray(rng.integers(0, s + 1, size=(b,)), jnp.int32)
    out = A.decode_attention(q, k, v, lengths)
    ref = R.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_decode_attention_zero_length_is_zero():
    rng = np.random.default_rng(0)
    q = _rand(rng, (2, 2, 16), jnp.float32)
    k = _rand(rng, (2, 2, 64, 16), jnp.float32)
    v = _rand(rng, (2, 2, 64, 16), jnp.float32)
    out = A.decode_attention(q, k, v, jnp.zeros((2,), jnp.int32))
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_decode_attention_full_length_equals_softmax():
    """length == S must equal plain softmax attention."""
    rng = np.random.default_rng(1)
    b, h, s, dh = 2, 2, 128, 32
    q = _rand(rng, (b, h, dh), jnp.float32)
    k = _rand(rng, (b, h, s, dh), jnp.float32)
    v = _rand(rng, (b, h, s, dh), jnp.float32)
    out = A.decode_attention(q, k, v, jnp.full((b,), s, jnp.int32))
    scores = jnp.einsum("bhd,bhsd->bhs", q, k) / np.sqrt(dh)
    probs = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bhs,bhsd->bhd", probs, v)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_decode_attention_is_batch_independent():
    """Masked/padded slots must not affect other slots."""
    rng = np.random.default_rng(2)
    b, h, s, dh = 4, 2, 64, 16
    q = _rand(rng, (b, h, dh), jnp.float32)
    k = _rand(rng, (b, h, s, dh), jnp.float32)
    v = _rand(rng, (b, h, s, dh), jnp.float32)
    lengths = jnp.asarray([5, 10, 20, 40], jnp.int32)
    full = A.decode_attention(q, k, v, lengths)
    solo = A.decode_attention(q[1:2], k[1:2], v[1:2], lengths[1:2])
    np.testing.assert_allclose(full[1:2], solo, rtol=1e-6, atol=1e-6)


@given(kv_block=st.sampled_from([32, 64, 128]))
def test_decode_attention_block_size_invariance(kv_block):
    rng = np.random.default_rng(3)
    b, h, s, dh = 2, 2, 256, 32
    q = _rand(rng, (b, h, dh), jnp.float32)
    k = _rand(rng, (b, h, s, dh), jnp.float32)
    v = _rand(rng, (b, h, s, dh), jnp.float32)
    lengths = jnp.asarray([100, 256], jnp.int32)
    a = A.decode_attention(q, k, v, lengths, kv_block=kv_block)
    ref = R.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(a, ref, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# chunked prefill attention
# ---------------------------------------------------------------------------

@given(
    b=st.integers(1, 4),
    h=st.sampled_from([1, 2, 4]),
    c=st.sampled_from([8, 16, 32]),
    s=st.sampled_from([128, 256]),
    dh=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunk_attention_matches_ref(b, h, c, s, dh, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h, c, dh), jnp.float32)
    k = _rand(rng, (b, h, s, dh), jnp.float32)
    v = _rand(rng, (b, h, s, dh), jnp.float32)
    base = jnp.asarray(rng.integers(0, s - c + 1, size=(b,)), jnp.int32)
    out = A.prefix_chunk_attention(q, k, v, base)
    ref = R.prefix_chunk_attention_ref(q, k, v, base)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_chunk_attention_first_row_sees_only_base_plus_one():
    """Row 0 with base=0 attends only to cache row 0 => output == v[0]."""
    rng = np.random.default_rng(4)
    b, h, c, s, dh = 1, 1, 4, 64, 8
    q = _rand(rng, (b, h, c, dh), jnp.float32)
    k = _rand(rng, (b, h, s, dh), jnp.float32)
    v = _rand(rng, (b, h, s, dh), jnp.float32)
    out = A.prefix_chunk_attention(q, k, v, jnp.zeros((b,), jnp.int32))
    np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], rtol=1e-5, atol=1e-5)


def test_chunk_attention_is_causal():
    """Perturbing cache rows BEYOND base+t must not change row t."""
    rng = np.random.default_rng(5)
    b, h, c, s, dh = 1, 2, 8, 64, 16
    q = _rand(rng, (b, h, c, dh), jnp.float32)
    k = np.asarray(_rand(rng, (b, h, s, dh), jnp.float32))
    v = np.asarray(_rand(rng, (b, h, s, dh), jnp.float32))
    base = jnp.asarray([10], jnp.int32)
    out1 = A.prefix_chunk_attention(q, jnp.asarray(k), jnp.asarray(v), base)
    k2, v2 = k.copy(), v.copy()
    k2[:, :, 30:, :] = 99.0  # rows 30.. are beyond base+c-1 = 17
    v2[:, :, 30:, :] = -99.0
    out2 = A.prefix_chunk_attention(q, jnp.asarray(k2), jnp.asarray(v2), base)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# fused AdaLN DiT block
# ---------------------------------------------------------------------------

@given(
    b=st.integers(1, 3),
    n=st.sampled_from([16, 64, 128]),
    d=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_adaln_block_matches_ref(b, n, d, seed):
    rng = np.random.default_rng(seed)
    f = 4 * d
    w = lambda *s: _rand(rng, s, jnp.float32) * 0.05
    x, t = w(b, n, d), w(b, d)
    wq, wk, wv, wo = w(d, d), w(d, d), w(d, d), w(d, d)
    w1, w2 = w(d, f), w(f, d)
    mw, mb = w(d, 6 * d), w(6 * d)
    out = D.adaln_block(x, t, wq, wk, wv, wo, w1, w2, mw, mb)
    ref = R.adaln_block_ref(x, t, wq, wk, wv, wo, w1, w2, mw, mb)
    np.testing.assert_allclose(out, ref, rtol=5e-5, atol=5e-5)


def test_adaln_block_zero_gates_is_identity():
    """mod_w = mod_b = 0 => gates are 0 => block is the identity."""
    rng = np.random.default_rng(6)
    b, n, d = 2, 32, 64
    f = 4 * d
    w = lambda *s: _rand(rng, s, jnp.float32)
    x = w(b, n, d)
    out = D.adaln_block(
        x, w(b, d), w(d, d), w(d, d), w(d, d), w(d, d), w(d, f), w(f, d),
        jnp.zeros((d, 6 * d), jnp.float32), jnp.zeros((6 * d,), jnp.float32),
    )
    np.testing.assert_allclose(out, x, rtol=1e-6, atol=1e-6)


def test_adaln_block_batch_independence():
    rng = np.random.default_rng(7)
    b, n, d = 3, 16, 64
    f = 4 * d
    w = lambda *s: _rand(rng, s, jnp.float32) * 0.05
    x, t = w(b, n, d), w(b, d)
    ws = [w(d, d), w(d, d), w(d, d), w(d, d), w(d, f), w(f, d), w(d, 6 * d), w(6 * d)]
    full = D.adaln_block(x, t, *ws)
    solo = D.adaln_block(x[2:3], t[2:3], *ws)
    np.testing.assert_allclose(full[2:3], solo, rtol=2e-5, atol=2e-5)
