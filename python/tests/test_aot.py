"""AOT pipeline sanity: manifest structure, weight-blob layout, and HLO
text loadability for a tiny model set (fast — avoids relowering the zoo).
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import configs as C
from compile import layers as L

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_weight_blob_matches_leaves(tmp_path):
    b = aot.Builder(str(tmp_path), verbose=False)
    cfg = C.ArConfig("t", vocab=32, d_model=16, n_layers=1, n_heads=2,
                     d_head=8, d_ff=32, max_seq=32)
    params = L.ar_init(cfg, 0)
    names = b.add_model("t", "ar", cfg, params)
    assert names == sorted(params)
    rec = b.manifest["models"]["t"]
    blob = np.fromfile(tmp_path / rec["weights"]["file"], dtype=np.float32)
    total = sum(l["size"] for l in rec["weights"]["leaves"])
    assert blob.size == total
    # Offsets are contiguous and in leaf order.
    off = 0
    for leaf in rec["weights"]["leaves"]:
        assert leaf["offset"] == off
        expect = np.asarray(params[leaf["name"]], np.float32).ravel()
        got = blob[off:off + leaf["size"]]
        np.testing.assert_array_equal(got, expect)
        off += leaf["size"]


def test_entry_io_specs(tmp_path):
    cfg = C.ArConfig("t", vocab=32, d_model=16, n_layers=1, n_heads=2,
                     d_head=8, d_ff=32, max_seq=32)
    b = aot.Builder(str(tmp_path), verbose=False)
    aot.build_ar(b, cfg, 0, scan=False)
    b.finish()
    m = json.load(open(tmp_path / "manifest.json"))
    ent = m["models"]["t"]["entries"]["decode.b1"]
    names = [i["name"] for i in ent["inputs"]]
    assert names == ["token", "kv", "length"]
    assert ent["inputs"][1]["shape"] == [1, 2, 1, 2, 32, 8]
    outs = [o["name"] for o in ent["outputs"]]
    assert outs == ["logits", "hidden", "kv"]
    assert ent["outputs"][0]["shape"] == [1, 32]
    assert (tmp_path / ent["file"]).exists()
    text = open(tmp_path / ent["file"]).read()
    assert text.lstrip().startswith("HloModule")


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
def test_shipped_manifest_is_complete():
    m = json.load(open(os.path.join(ART, "manifest.json")))
    assert m["version"] == aot.MANIFEST_VERSION
    # Every pipeline model the Rust presets reference must be present.
    for name in ["thinker25", "thinker3", "talker25", "talker3", "mimo",
                 "bagel_und", "voc_dit25", "voc_cnn3", "bagel_t2i",
                 "bagel_i2i", "qwen_image", "qwen_image_edit", "wan22_t2v",
                 "wan22_i2v", "enc25", "enc3", "mimo_codec"]:
        assert name in m["models"], name
    for name, rec in m["models"].items():
        assert os.path.exists(os.path.join(ART, rec["weights"]["file"])), name
        for ename, ent in rec["entries"].items():
            assert os.path.exists(os.path.join(ART, ent["file"])), (name, ename)
            for io in ent["inputs"] + ent["outputs"]:
                assert io["dtype"] in ("f32", "i32")
                assert all(d > 0 for d in io["shape"])
    # AR models expose the decode buckets the scheduler relies on.
    for ar in ["thinker25", "thinker3", "talker25", "talker3"]:
        for bb in C.AR_DECODE_BUCKETS:
            assert f"decode.b{bb}" in m["models"][ar]["entries"]
