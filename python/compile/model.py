"""L2 stage functions: the compute graphs AOT-lowered to HLO artifacts.

Every public function here is a pure function ``fn(params, *tensors)`` that
``aot.py`` lowers once per (model, batch-bucket) and dumps as HLO text.
The Rust L3 coordinator replays these executables from its engines:

  AR stages   : ``ar_prefill_chunk`` / ``ar_decode_step`` / ``ar_decode_scan``
  DiT stages  : ``dit_step`` (vocoder + image/video, CFG folded in)
  CNN vocoder : ``cnn_vocoder``
  Encoders    : ``mm_encode`` (audio/image/video -> embeddings)
  Patch codec : ``patch_encode`` / ``patch_decode`` (MiMo-Audio)

Conventions shared with Rust (do not change without bumping manifest
version): KV layout [L, 2, B, H, S, dh]; `length`/`base` are i32[B] counts
of valid cache rows; new decode token is written at row `length` and
attention covers `length + 1` rows; chunk rows are written at
`base .. base+C` and row t attends to `[0, base+t]`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ArConfig, CnnVocoderConfig, DitConfig, EncoderConfig, PatchCodecConfig
from .kernels.attention import decode_attention, prefix_chunk_attention
from .kernels.dit_block import adaln_block
from .layers import (
    full_attention,
    gelu,
    kv_write_rows,
    layer_norm,
    rms_norm,
    sinusoidal_embed,
)

# ---------------------------------------------------------------------------
# AR stage
# ---------------------------------------------------------------------------


def _ar_layer_decode(params, prefix, cfg: ArConfig, x, kv_l, length):
    """One decoder layer for a single new token.

    x: [B, D]; kv_l: [2, B, H, S, dh]; length: [B].
    Returns (x', kv_l').
    """
    b, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    xn = rms_norm(x, params[prefix + "ln1"])
    q = jnp.dot(xn, params[prefix + "wq"]).reshape(b, h, dh)
    k = jnp.dot(xn, params[prefix + "wk"]).reshape(b, h, 1, dh)
    v = jnp.dot(xn, params[prefix + "wv"]).reshape(b, h, 1, dh)
    kv_l = kv_write_rows(kv_l, k, v, length)
    att = decode_attention(q, kv_l[0], kv_l[1], length + 1)
    x = x + jnp.dot(att.reshape(b, h * dh), params[prefix + "wo"])
    xn = rms_norm(x, params[prefix + "ln2"])
    x = x + jnp.dot(gelu(jnp.dot(xn, params[prefix + "w1"])), params[prefix + "w2"])
    return x, kv_l


def ar_decode_step(params, cfg: ArConfig, token, cond, kv, length):
    """One decode iteration for a batch of sequences.

    token: [B] i32; cond: [B, cond_dim] f32 (absent when cond_dim == 0);
    kv: [L, 2, B, H, S, dh]; length: [B] i32 (valid rows BEFORE this token).

    Returns (logits [B, V], hidden [B, D], new_kv).
    """
    b = token.shape[0]
    pos = jnp.clip(length, 0, cfg.max_seq - 1)
    x = params["embed"][token] + params["pos"][pos]
    if cfg.cond_dim:
        x = x + jnp.dot(cond, params["cond_proj"])
    new_layers = []
    for l in range(cfg.n_layers):
        x, kv_l = _ar_layer_decode(params, f"l{l:02d}.", cfg, x, kv[l], length)
        new_layers.append(kv_l)
    new_kv = jnp.stack(new_layers)
    hidden = rms_norm(x, params["lnf"])
    logits = jnp.dot(hidden, params["lm_head"])
    return logits, hidden, new_kv


def ar_prefill_chunk(params, cfg: ArConfig, tokens, mm_embeds, mm_mask, kv, base):
    """One chunked-prefill iteration.

    tokens: [B, C] i32; mm_embeds: [B, C, E] f32 where E = cond_dim if the
    model has a conditioning stream (Talker: Thinker hidden prefix) else
    d_model (Thinker: multimodal encoder output); mm_mask: [B, C] f32 in
    {0,1} selecting the embedding stream over the token stream;
    kv: [L, 2, B, H, S, dh]; base: [B] i32 rows already in cache.

    Returns (logits [B, C, V], hidden [B, C, D], new_kv).
    """
    b, c = tokens.shape
    d = cfg.d_model
    h, dh = cfg.n_heads, cfg.d_head
    pos = jnp.clip(base[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :], 0, cfg.max_seq - 1)
    tok_x = params["embed"][tokens]
    if cfg.cond_dim:
        mm_x = jnp.einsum("bce,ed->bcd", mm_embeds, params["cond_proj"])
    else:
        mm_x = mm_embeds
    x = jnp.where(mm_mask[:, :, None] > 0.5, mm_x, tok_x) + params["pos"][pos]
    new_layers = []
    for l in range(cfg.n_layers):
        p = f"l{l:02d}."
        xn = rms_norm(x, params[p + "ln1"])
        q = jnp.einsum("bcd,de->bce", xn, params[p + "wq"]).reshape(b, c, h, dh).transpose(0, 2, 1, 3)
        k = jnp.einsum("bcd,de->bce", xn, params[p + "wk"]).reshape(b, c, h, dh).transpose(0, 2, 1, 3)
        v = jnp.einsum("bcd,de->bce", xn, params[p + "wv"]).reshape(b, c, h, dh).transpose(0, 2, 1, 3)
        kv_l = kv_write_rows(kv[l], k, v, base)
        att = prefix_chunk_attention(q, kv_l[0], kv_l[1], base)  # [B,H,C,dh]
        att = att.transpose(0, 2, 1, 3).reshape(b, c, h * dh)
        x = x + jnp.einsum("bce,ed->bcd", att, params[p + "wo"])
        xn = rms_norm(x, params[p + "ln2"])
        x = x + jnp.einsum("bcf,fd->bcd", gelu(jnp.einsum("bcd,df->bcf", xn, params[p + "w1"])), params[p + "w2"])
        new_layers.append(kv_l)
    new_kv = jnp.stack(new_layers)
    hidden = rms_norm(x, params["lnf"])
    logits = jnp.einsum("bcd,dv->bcv", hidden, params["lm_head"])
    return logits, hidden, new_kv


def ar_decode_scan(params, cfg: ArConfig, token0, cond, kv, length, active0, eos_ids, n_steps: int):
    """Fused multi-step greedy decode ("execution-graph compilation" mode).

    Runs ``n_steps`` decode iterations inside one executable, sampling
    greedily and freezing sequences that emit EOS.  This is the analog of
    CUDA-graph / compiled-decode serving: per-step host round-trips
    (KV marshaling, dispatch) amortize over n_steps.

    token0: [B] i32 first input token; cond: [B, cond_dim] (fixed across
    the scanned steps, matching the paper's "concatenate the SAME Thinker
    hidden states at each decoding step"); active0: [B] f32 {0,1};
    eos_ids: [B] i32 per-lane stop token (pass -1 to never stop, the
    ignore_eos serving mode).

    Returns (tokens [B, K] i32, hiddens [B, K, D], new_kv, new_length,
    active [B] f32).
    """
    def body(carry, _):
        token, kv_c, length_c, active = carry
        logits, hidden, kv_n = ar_decode_step(params, cfg, token, cond, kv_c, length_c)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        is_active = active > 0.5
        emitted = jnp.where(is_active, nxt, jnp.zeros_like(nxt))
        new_active = jnp.where(is_active & (nxt != eos_ids), 1.0, 0.0).astype(jnp.float32)
        # Frozen sequences must not advance their cache.
        kv_keep = jnp.where(is_active[None, None, :, None, None, None], kv_n, kv_c)
        len_next = jnp.where(is_active, length_c + 1, length_c)
        # Guard cache overflow inside the scan.
        len_next = jnp.minimum(len_next, cfg.max_seq - 1)
        return (emitted, kv_keep, len_next, new_active), (emitted, hidden)

    carry0 = (token0, kv, length, active0)
    (tok_f, kv_f, len_f, act_f), (toks, hiddens) = jax.lax.scan(
        body, carry0, None, length=n_steps
    )
    return (
        toks.transpose(1, 0),            # [B, K]
        hiddens.transpose(1, 0, 2),      # [B, K, D]
        kv_f,
        len_f,
        act_f,
    )


# ---------------------------------------------------------------------------
# Multimodal encoder stage
# ---------------------------------------------------------------------------


def mm_encode(params, cfg: EncoderConfig, feats, t_mask):
    """Multimodal encoder: features -> embeddings in the Thinker's width.

    feats: [B, T, feat_dim]; t_mask: [B, T] f32 {0,1} valid-frame mask.
    Returns [B, T, d_out].
    """
    x = jnp.einsum("btf,fd->btd", feats, params["in_proj"]) + params["pos"][None, :, :]
    x = x * t_mask[:, :, None]
    for l in range(cfg.n_layers):
        p = f"l{l:02d}."
        xn = rms_norm(x, params[p + "ln1"])
        x = x + full_attention(xn, params[p + "wq"], params[p + "wk"],
                               params[p + "wv"], params[p + "wo"], cfg.n_heads)
        xn = rms_norm(x, params[p + "ln2"])
        x = x + jnp.einsum("btf,fd->btd", gelu(jnp.einsum("btd,df->btf", xn, params[p + "w1"])), params[p + "w2"])
    out = jnp.einsum("btd,de->bte", x, params["out_proj"])
    return out * t_mask[:, :, None]


# ---------------------------------------------------------------------------
# DiT stage (vocoder + image/video), CFG folded into the executable
# ---------------------------------------------------------------------------


def _dit_trunk(params, cfg: DitConfig, x, t_emb):
    for l in range(cfg.n_layers):
        p = f"l{l:02d}."
        x = adaln_block(
            x, t_emb,
            params[p + "wq"], params[p + "wk"], params[p + "wv"], params[p + "wo"],
            params[p + "w1"], params[p + "w2"], params[p + "mod_w"], params[p + "mod_b"],
            n_heads=cfg.n_heads,
        )
    x = layer_norm(x) * params["out_ln"]
    return jnp.einsum("bnd,dl->bnl", x, params["out_proj"])


def dit_step(params, cfg: DitConfig, latent, cond, cond_tokens, t, cfg_scale):
    """One denoising step (epsilon prediction) with classifier-free guidance.

    latent: [B, N, latent_dim]; cond: [B, cond_dim] (zeros if cond_dim==0);
    cond_tokens: [B, N, cond_tokens_dim] per-token conditioning (vocoder
    codec embeds; zeros if unused); t: [B] f32 noise level in [0,1];
    cfg_scale: [B] f32 guidance strength (1.0 = no guidance branch mixing).

    Returns (eps [B, N, latent_dim], t_mod [B, D]) where t_mod is the
    modulation embedding exposed for the TeaCache-style step cache at L3.
    """
    x = jnp.einsum("bnl,ld->bnd", latent, params["in_proj"]) + params["pos"][None, :, :]
    if cfg.cond_tokens_dim:
        x = x + jnp.einsum("bnc,cd->bnd", cond_tokens, params["cond_tok_proj"])
    t_base = sinusoidal_embed(t, cfg.d_model)
    t_base = jnp.dot(gelu(jnp.dot(t_base, params["t_mlp1"])), params["t_mlp2"])
    if cfg.cond_dim:
        t_cond = t_base + jnp.dot(cond, params["cond_proj"])
        eps_c = _dit_trunk(params, cfg, x, t_cond)
        eps_u = _dit_trunk(params, cfg, x, t_base)
        eps = eps_u + cfg_scale[:, None, None] * (eps_c - eps_u)
        t_mod = t_cond
    else:
        eps = _dit_trunk(params, cfg, x, t_base)
        t_mod = t_base
    return eps, t_mod


# ---------------------------------------------------------------------------
# CNN vocoder stage (Qwen3-Omni style lightweight waveform head)
# ---------------------------------------------------------------------------


def cnn_vocoder(params, cfg: CnnVocoderConfig, tokens):
    """Codec tokens -> waveform chunk.

    tokens: [B, T] i32 codec ids.  Returns wave [B, T * upsample] f32.
    """
    up1 = 4
    up2 = cfg.upsample // up1
    x = params["embed"][tokens]                       # [B, T, de]
    x = jnp.einsum("btd,dc->btc", x, params["in_proj"])
    x = jnp.repeat(x, up1, axis=1)                    # [B, 4T, C]
    x = _conv1d(x, params["conv1"])
    x = gelu(x)
    x = jnp.repeat(x, up2, axis=1)                    # [B, 16T, C]
    x = _conv1d(x, params["conv2"])
    x = jnp.tanh(x)
    wave = jnp.einsum("btc,co->bto", x, params["out_proj"])[:, :, 0]
    return wave


def _conv1d(x, w):
    """x: [B, T, Cin], w: [K, Cin, Cout] -> same-padded conv."""
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(1,),
        padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
    )


# ---------------------------------------------------------------------------
# MiMo-Audio patch codec stages
# ---------------------------------------------------------------------------


def patch_encode(params, cfg: PatchCodecConfig, feats):
    """Audio patches -> backbone embeddings.  feats: [B, T, patch_dim]."""
    x = gelu(jnp.einsum("btp,pd->btd", feats, params["enc_w1"]))
    return jnp.einsum("btd,de->bte", x, params["enc_w2"])


def patch_decode(params, cfg: PatchCodecConfig, tokens):
    """Audio tokens -> waveform patches.  tokens: [B, T] i32.

    Returns [B, T, samples_per_patch].
    """
    x = params["dec_embed"][tokens]
    x = gelu(jnp.einsum("btd,de->bte", x, params["dec_w1"]))
    return jnp.tanh(jnp.einsum("btd,ds->bts", x, params["dec_w2"]))
