"""Model-zoo configurations for the omni-serve reproduction.

These are laptop-scale stand-ins for the paper's models (DESIGN.md §7):
the pipeline *topology* (Thinker->Talker->Vocoder, AR+DiT, patch codec)
and the relative scale ordering (Qwen3 Thinker > Qwen2.5 Thinker > Talker)
are preserved; parameter counts are scaled to CPU-PJRT practicality.

Every config here is mirrored in the manifest consumed by the Rust
runtime, so Rust never hard-codes shapes.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArConfig:
    """Autoregressive decoder stage (Thinker / Talker / MiMo backbone)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_head: int
    d_ff: int
    max_seq: int
    # Per-step conditioning width (Talker: Thinker hidden size). 0 = none.
    cond_dim: int = 0
    eos_id: int = 2

    @property
    def kv_floats_per_slot(self) -> int:
        return self.n_layers * 2 * self.n_heads * self.max_seq * self.d_head

    @property
    def n_params(self) -> int:
        d = self.d_model
        per_layer = 3 * d * self.n_heads * self.d_head + self.n_heads * self.d_head * d
        per_layer += d * self.d_ff + self.d_ff * d + 2 * d
        total = self.vocab * d + self.max_seq * d + self.n_layers * per_layer
        total += d + d * self.vocab
        if self.cond_dim:
            total += self.cond_dim * d
        return total


@dataclass(frozen=True)
class DitConfig:
    """Diffusion-transformer stage (vocoder or image/video generator)."""

    name: str
    n_tokens: int      # latent tokens per sample
    latent_dim: int    # channels per latent token
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    cond_dim: int      # conditioning vector width (text/codec summary)
    # Per-token conditioning stream (vocoder codec embeds); 0 = none.
    cond_tokens_dim: int = 0
    default_steps: int = 10


@dataclass(frozen=True)
class CnnVocoderConfig:
    """Lightweight CNN vocoder (Qwen3-Omni style)."""

    name: str
    vocab: int        # codec vocabulary
    t_frames: int     # codec frames per chunk
    d_embed: int
    channels: int
    upsample: int     # total waveform samples per frame


@dataclass(frozen=True)
class EncoderConfig:
    """Multimodal input encoder (audio/image/video -> embeddings)."""

    name: str
    feat_dim: int
    t_max: int
    d_inner: int
    n_layers: int
    n_heads: int
    d_out: int


@dataclass(frozen=True)
class PatchCodecConfig:
    """MiMo-Audio patch encoder/decoder pair."""

    name: str
    patch_dim: int     # input feature dim per audio patch
    t_max: int         # patches per call
    d_model: int       # backbone embedding width
    vocab: int         # audio token vocabulary
    samples_per_patch: int


# --------------------------------------------------------------------------
# The model zoo.  Names are referenced by python/compile/aot.py and by the
# Rust config presets (rust/src/config/presets.rs).
# --------------------------------------------------------------------------

AR_MODELS = {
    # Qwen2.5-Omni sim: 7B Thinker -> small; Talker smaller still.
    "thinker25": ArConfig("thinker25", vocab=4096, d_model=256, n_layers=4,
                          n_heads=4, d_head=64, d_ff=1024, max_seq=256),
    # Qwen3-Omni sim: 30B Thinker -> deliberately larger than thinker25.
    "thinker3": ArConfig("thinker3", vocab=4096, d_model=384, n_layers=6,
                         n_heads=6, d_head=64, d_ff=1536, max_seq=256),
    "talker25": ArConfig("talker25", vocab=2048, d_model=192, n_layers=3,
                         n_heads=4, d_head=48, d_ff=768, max_seq=256,
                         cond_dim=256),
    "talker3": ArConfig("talker3", vocab=2048, d_model=256, n_layers=4,
                        n_heads=4, d_head=64, d_ff=1024, max_seq=256,
                        cond_dim=384),
    # MiMo-Audio backbone.
    "mimo": ArConfig("mimo", vocab=2048, d_model=256, n_layers=4,
                     n_heads=4, d_head=64, d_ff=1024, max_seq=256),
    # BAGEL understanding expert (MoT understanding half).
    "bagel_und": ArConfig("bagel_und", vocab=4096, d_model=256, n_layers=4,
                          n_heads=4, d_head=64, d_ff=1024, max_seq=256),
}

DIT_MODELS = {
    # Qwen2.5-Omni DiT vocoder: codec frames -> mel-ish latents.
    "voc_dit25": DitConfig("voc_dit25", n_tokens=64, latent_dim=32,
                           d_model=192, n_layers=3, n_heads=4, d_ff=768,
                           cond_dim=0, cond_tokens_dim=48, default_steps=10),
    # BAGEL generation expert.
    "bagel_t2i": DitConfig("bagel_t2i", n_tokens=256, latent_dim=16,
                           d_model=256, n_layers=4, n_heads=4, d_ff=1024,
                           cond_dim=256, default_steps=24),
    "bagel_i2i": DitConfig("bagel_i2i", n_tokens=512, latent_dim=16,
                           d_model=256, n_layers=4, n_heads=4, d_ff=1024,
                           cond_dim=256, default_steps=24),
    # Qwen-Image / Qwen-Image-Edit sims (wider trunk).
    "qwen_image": DitConfig("qwen_image", n_tokens=256, latent_dim=16,
                            d_model=320, n_layers=4, n_heads=4, d_ff=1280,
                            cond_dim=256, default_steps=20),
    "qwen_image_edit": DitConfig("qwen_image_edit", n_tokens=512, latent_dim=16,
                                 d_model=320, n_layers=4, n_heads=4, d_ff=1280,
                                 cond_dim=256, default_steps=20),
    # Wan2.2 video sims (more latent tokens = frames x patches).
    "wan22_t2v": DitConfig("wan22_t2v", n_tokens=384, latent_dim=16,
                           d_model=256, n_layers=4, n_heads=4, d_ff=1024,
                           cond_dim=256, default_steps=20),
    "wan22_i2v": DitConfig("wan22_i2v", n_tokens=448, latent_dim=16,
                           d_model=256, n_layers=4, n_heads=4, d_ff=1024,
                           cond_dim=256, default_steps=20),
}

CNN_VOCODERS = {
    # Qwen3-Omni lightweight CNN vocoder.
    "voc_cnn3": CnnVocoderConfig("voc_cnn3", vocab=2048, t_frames=64,
                                 d_embed=64, channels=64, upsample=16),
}

ENCODERS = {
    "enc25": EncoderConfig("enc25", feat_dim=64, t_max=128, d_inner=128,
                           n_layers=2, n_heads=4, d_out=256),
    "enc3": EncoderConfig("enc3", feat_dim=64, t_max=128, d_inner=128,
                          n_layers=2, n_heads=4, d_out=384),
}

PATCH_CODECS = {
    "mimo_codec": PatchCodecConfig("mimo_codec", patch_dim=64, t_max=64,
                                   d_model=256, vocab=2048,
                                   samples_per_patch=128),
}

# Chunk size for chunked prefill; decode-scan unroll length.
PREFILL_CHUNK = 32
SCAN_STEPS = 8

AR_DECODE_BUCKETS = (1, 2, 4, 8)
AR_PREFILL_BUCKETS = (1, 2, 4)
AR_SCAN_BUCKETS = (1, 2, 4)
DIT_VOC_BUCKETS = (1, 2, 4)
CNN_VOC_BUCKETS = (1, 2, 4)
IMAGE_DIT_BUCKETS = (1,)
ENCODER_BUCKETS = (1, 4)
PATCH_BUCKETS = (1, 4)

# Which AR models get a decode_scan entry (long-generation stages).
SCAN_MODELS = ("talker25", "talker3", "mimo")


def config_dict(cfg) -> dict:
    """Dataclass -> plain dict for the JSON manifest."""
    return dataclasses.asdict(cfg)
