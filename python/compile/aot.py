"""AOT compiler: lower every stage function to HLO text + write the manifest.

Run once at build time (``make artifacts``); Python is never on the request
path.  Produces, under ``--out`` (default ``../artifacts``):

  <model>.<fn>.b<B>[...].hlo.txt   one executable per (model, entry, bucket)
  <model>.weights.bin              raw little-endian f32 weight blob
  manifest.json                    configs + weight leaf order + entry IO specs

Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the Rust
`xla` crate) rejects; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs as C
from . import layers as L
from . import model as M

MANIFEST_VERSION = 3


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


_DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}


def _iospec(name, s):
    return {"name": name, "shape": [int(d) for d in s.shape],
            "dtype": _DTYPE_NAMES[jnp.dtype(s.dtype)]}


class Builder:
    def __init__(self, out_dir: str, verbose: bool = True):
        self.out = out_dir
        self.manifest = {"version": MANIFEST_VERSION, "models": {}}
        self.verbose = verbose
        os.makedirs(out_dir, exist_ok=True)

    def log(self, msg):
        if self.verbose:
            print(msg, flush=True)

    # -- weights ------------------------------------------------------------

    def add_model(self, name: str, kind: str, cfg, params: dict):
        leaf_names = sorted(params)
        blob = bytearray()
        leaves = []
        for n in leaf_names:
            arr = np.asarray(params[n], dtype=np.float32)
            leaves.append({"name": n, "shape": list(arr.shape),
                           "offset": len(blob) // 4, "size": int(arr.size)})
            blob += arr.tobytes()
        wfile = f"{name}.weights.bin"
        with open(os.path.join(self.out, wfile), "wb") as f:
            f.write(bytes(blob))
        self.manifest["models"][name] = {
            "kind": kind,
            "config": C.config_dict(cfg),
            "weights": {"file": wfile, "dtype": "f32", "leaves": leaves},
            "entries": {},
        }
        self.log(f"[aot] {name}: {len(blob)//4} weight floats -> {wfile}")
        return leaf_names

    # -- entries ------------------------------------------------------------

    def add_entry(self, model: str, entry: str, fn, weight_specs, arg_specs,
                  arg_names, out_names, donate=()):
        """Lower fn(weights_tuple, *args) and record the entry.

        ``donate`` lists arg names whose buffers the executable may update
        in place (input_output_alias in the HLO — XLA then avoids copying
        the KV cache on every decode step; see EXPERIMENTS.md §Perf).
        """
        t0 = time.time()
        # keep_unused: entries that use a subset of the weight leaves
        # (e.g. patch_codec encode/decode) must still accept ALL leaves,
        # since the Rust runtime passes the full weight set per model.
        donate_argnums = tuple(1 + arg_names.index(d) for d in donate)
        lowered = jax.jit(fn, keep_unused=True,
                          donate_argnums=donate_argnums).lower(
            tuple(weight_specs), *arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{model}.{entry}.hlo.txt"
        with open(os.path.join(self.out, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, tuple(weight_specs), *arg_specs)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        assert len(outs) == len(out_names), (model, entry, len(outs), out_names)
        self.manifest["models"][model]["entries"][entry] = {
            "file": fname,
            "inputs": [_iospec(n, s) for n, s in zip(arg_names, arg_specs)],
            "outputs": [_iospec(n, s) for n, s in zip(out_names, outs)],
        }
        self.log(f"[aot]   {model}.{entry}: {len(text)//1024} KiB HLO "
                 f"({time.time()-t0:.1f}s)")

    def finish(self):
        path = os.path.join(self.out, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        self.log(f"[aot] wrote {path}")


# ---------------------------------------------------------------------------
# Per-family builders
# ---------------------------------------------------------------------------


def build_ar(b: Builder, cfg: C.ArConfig, seed: int, *, scan: bool):
    params = L.ar_init(cfg, seed)
    names = b.add_model(cfg.name, "ar", cfg, params)
    wspecs = [_spec(params[n].shape) for n in names]

    def bind(f):
        def wrapped(ws, *args):
            return f(dict(zip(names, ws)), cfg, *args)
        return wrapped

    kv = lambda bb: _spec(L.kv_shape(cfg, bb))
    ib = lambda bb: _spec((bb,), jnp.int32)
    fb = lambda *s: _spec(s)

    # decode
    for bb in C.AR_DECODE_BUCKETS:
        if cfg.cond_dim:
            fn = bind(M.ar_decode_step)
            args = [ib(bb), fb(bb, cfg.cond_dim), kv(bb), ib(bb)]
            argn = ["token", "cond", "kv", "length"]
        else:
            fn = bind(lambda p, c, token, kvv, length:
                      M.ar_decode_step(p, c, token, None, kvv, length))
            args = [ib(bb), kv(bb), ib(bb)]
            argn = ["token", "kv", "length"]
        b.add_entry(cfg.name, f"decode.b{bb}", fn, wspecs, args, argn,
                    ["logits", "hidden", "kv"], donate=("kv",))

    # prefill
    cch = C.PREFILL_CHUNK
    emb_dim = cfg.cond_dim if cfg.cond_dim else cfg.d_model
    for bb in C.AR_PREFILL_BUCKETS:
        fn = bind(M.ar_prefill_chunk)
        args = [_spec((bb, cch), jnp.int32), fb(bb, cch, emb_dim),
                fb(bb, cch), kv(bb), ib(bb)]
        argn = ["tokens", "mm_embeds", "mm_mask", "kv", "base"]
        b.add_entry(cfg.name, f"prefill.b{bb}.c{cch}", fn, wspecs, args, argn,
                    ["logits", "hidden", "kv"], donate=("kv",))

    # fused decode scan
    if scan:
        k = C.SCAN_STEPS
        for bb in C.AR_SCAN_BUCKETS:
            if cfg.cond_dim:
                fn = bind(functools.partial(M.ar_decode_scan, n_steps=k))
                args = [ib(bb), fb(bb, cfg.cond_dim), kv(bb), ib(bb), fb(bb),
                        ib(bb)]
                argn = ["token", "cond", "kv", "length", "active", "eos"]
            else:
                fn = bind(lambda p, c, token, kvv, length, active, eos:
                          M.ar_decode_scan(p, c, token, None, kvv, length,
                                           active, eos, n_steps=k))
                args = [ib(bb), kv(bb), ib(bb), fb(bb), ib(bb)]
                argn = ["token", "kv", "length", "active", "eos"]
            b.add_entry(cfg.name, f"scan.b{bb}.k{k}", fn, wspecs, args, argn,
                        ["tokens", "hiddens", "kv", "length", "active"],
                        donate=("kv",))


def build_encoder(b: Builder, cfg: C.EncoderConfig, seed: int):
    params = L.encoder_init(cfg, seed)
    names = b.add_model(cfg.name, "encoder", cfg, params)
    wspecs = [_spec(params[n].shape) for n in names]

    def fn(ws, feats, mask):
        return (M.mm_encode(dict(zip(names, ws)), cfg, feats, mask),)

    for bb in C.ENCODER_BUCKETS:
        args = [_spec((bb, cfg.t_max, cfg.feat_dim)), _spec((bb, cfg.t_max))]
        b.add_entry(cfg.name, f"encode.b{bb}", fn, wspecs, args,
                    ["feats", "mask"], ["embeds"])


def build_dit(b: Builder, cfg: C.DitConfig, seed: int, buckets):
    params = L.dit_init(cfg, seed)
    names = b.add_model(cfg.name, "dit", cfg, params)
    wspecs = [_spec(params[n].shape) for n in names]

    def fn(ws, latent, cond, cond_tokens, t, cfg_scale):
        return M.dit_step(dict(zip(names, ws)), cfg, latent, cond,
                          cond_tokens, t, cfg_scale)

    for bb in buckets:
        args = [
            _spec((bb, cfg.n_tokens, cfg.latent_dim)),
            _spec((bb, max(cfg.cond_dim, 1))),
            _spec((bb, cfg.n_tokens, max(cfg.cond_tokens_dim, 1))),
            _spec((bb,)),
            _spec((bb,)),
        ]
        argn = ["latent", "cond", "cond_tokens", "t", "cfg_scale"]
        b.add_entry(cfg.name, f"step.b{bb}", fn, wspecs, args, argn,
                    ["eps", "t_mod"])


def build_cnn_vocoder(b: Builder, cfg: C.CnnVocoderConfig, seed: int):
    params = L.cnn_vocoder_init(cfg, seed)
    names = b.add_model(cfg.name, "cnn_vocoder", cfg, params)
    wspecs = [_spec(params[n].shape) for n in names]

    def fn(ws, tokens):
        return (M.cnn_vocoder(dict(zip(names, ws)), cfg, tokens),)

    for bb in C.CNN_VOC_BUCKETS:
        args = [_spec((bb, cfg.t_frames), jnp.int32)]
        b.add_entry(cfg.name, f"vocode.b{bb}", fn, wspecs, args,
                    ["tokens"], ["wave"])


def build_patch_codec(b: Builder, cfg: C.PatchCodecConfig, seed: int):
    params = L.patch_codec_init(cfg, seed)
    names = b.add_model(cfg.name, "patch_codec", cfg, params)
    wspecs = [_spec(params[n].shape) for n in names]

    def enc(ws, feats):
        return (M.patch_encode(dict(zip(names, ws)), cfg, feats),)

    def dec(ws, tokens):
        return (M.patch_decode(dict(zip(names, ws)), cfg, tokens),)

    for bb in C.PATCH_BUCKETS:
        b.add_entry(cfg.name, f"encode.b{bb}", enc, wspecs,
                    [_spec((bb, cfg.t_max, cfg.patch_dim))], ["feats"],
                    ["embeds"])
        b.add_entry(cfg.name, f"decode.b{bb}", dec, wspecs,
                    [_spec((bb, cfg.t_max), jnp.int32)], ["tokens"],
                    ["patches"])


# ---------------------------------------------------------------------------


def build_all(out_dir: str, only=None, verbose=True):
    b = Builder(out_dir, verbose=verbose)
    seed = 20260203  # paper preprint date

    def want(name):
        return only is None or name in only

    for i, (name, cfg) in enumerate(sorted(C.AR_MODELS.items())):
        if want(name):
            build_ar(b, cfg, seed + i, scan=name in C.SCAN_MODELS)
    for i, (name, cfg) in enumerate(sorted(C.ENCODERS.items())):
        if want(name):
            build_encoder(b, cfg, seed + 100 + i)
    for i, (name, cfg) in enumerate(sorted(C.DIT_MODELS.items())):
        if want(name):
            buckets = C.DIT_VOC_BUCKETS if name.startswith("voc_") else C.IMAGE_DIT_BUCKETS
            build_dit(b, cfg, seed + 200 + i, buckets)
    for i, (name, cfg) in enumerate(sorted(C.CNN_VOCODERS.items())):
        if want(name):
            build_cnn_vocoder(b, cfg, seed + 300 + i)
    for i, (name, cfg) in enumerate(sorted(C.PATCH_CODECS.items())):
        if want(name):
            build_patch_codec(b, cfg, seed + 400 + i)
    b.finish()
    return b.manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="limit to these model names (debugging)")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args()
    t0 = time.time()
    m = build_all(args.out, only=args.only, verbose=not args.quiet)
    n_entries = sum(len(v["entries"]) for v in m["models"].values())
    print(f"[aot] done: {len(m['models'])} models, {n_entries} entries "
          f"in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
