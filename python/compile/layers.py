"""Shared L2 building blocks: parameter init and transformer primitives.

All stage functions in ``model.py`` are pure functions of
``(params: dict[str, Array], *tensors)``.  Params are flat string-keyed
dicts so that the AOT flattening order (sorted keys) is deterministic and
recordable in the manifest for the Rust runtime.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ArConfig, CnnVocoderConfig, DitConfig, EncoderConfig, PatchCodecConfig
from .kernels.attention import decode_attention, prefix_chunk_attention


def rms_norm(x, g, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def layer_norm(x, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)


def gelu(y):
    return 0.5 * y * (1.0 + jnp.tanh(jnp.sqrt(2.0 / jnp.pi) * (y + 0.044715 * y**3)))


def sinusoidal_embed(t, dim):
    """t: [B] float in [0, 1] -> [B, dim] sinusoidal timestep embedding."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = t[:, None] * 1000.0 * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def full_attention(x, wq, wk, wv, wo, n_heads):
    """Bidirectional (encoder) attention, [B, T, D] -> [B, T, D]."""
    b, t, d = x.shape
    dh = wq.shape[1] // n_heads
    q = jnp.einsum("btd,de->bte", x, wq).reshape(b, t, n_heads, dh).transpose(0, 2, 1, 3)
    k = jnp.einsum("btd,de->bte", x, wk).reshape(b, t, n_heads, dh).transpose(0, 2, 1, 3)
    v = jnp.einsum("btd,de->bte", x, wv).reshape(b, t, n_heads, dh).transpose(0, 2, 1, 3)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    att = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhts,bhsd->bhtd", att, v).transpose(0, 2, 1, 3).reshape(b, t, -1)
    return jnp.einsum("bte,ed->btd", o, wo)


# ---------------------------------------------------------------------------
# Parameter init.  Scaled-normal init with a fixed per-model seed so that
# `make artifacts` is reproducible byte-for-byte.
# ---------------------------------------------------------------------------

def _normal(key, shape, scale):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(jnp.float32)


def ar_init(cfg: ArConfig, seed: int) -> dict:
    key = jax.random.PRNGKey(seed)
    d, dh, h = cfg.d_model, cfg.d_head, cfg.n_heads
    params = {}
    ks = jax.random.split(key, 8 + cfg.n_layers * 8)
    it = iter(range(len(ks)))
    s = 0.02
    params["embed"] = _normal(ks[next(it)], (cfg.vocab, d), s)
    params["pos"] = _normal(ks[next(it)], (cfg.max_seq, d), s)
    if cfg.cond_dim:
        params["cond_proj"] = _normal(ks[next(it)], (cfg.cond_dim, d), s)
    for l in range(cfg.n_layers):
        p = f"l{l:02d}."
        params[p + "ln1"] = jnp.ones((d,), jnp.float32)
        params[p + "wq"] = _normal(ks[next(it)], (d, h * dh), s)
        params[p + "wk"] = _normal(ks[next(it)], (d, h * dh), s)
        params[p + "wv"] = _normal(ks[next(it)], (d, h * dh), s)
        params[p + "wo"] = _normal(ks[next(it)], (h * dh, d), s)
        params[p + "ln2"] = jnp.ones((d,), jnp.float32)
        params[p + "w1"] = _normal(ks[next(it)], (d, cfg.d_ff), s)
        params[p + "w2"] = _normal(ks[next(it)], (cfg.d_ff, d), s)
    params["lnf"] = jnp.ones((d,), jnp.float32)
    params["lm_head"] = _normal(ks[next(it)], (d, cfg.vocab), s)
    return params


def dit_init(cfg: DitConfig, seed: int) -> dict:
    key = jax.random.PRNGKey(seed)
    d = cfg.d_model
    params = {}
    ks = jax.random.split(key, 10 + cfg.n_layers * 10)
    it = iter(range(len(ks)))
    s = 0.02
    params["in_proj"] = _normal(ks[next(it)], (cfg.latent_dim, d), s)
    params["pos"] = _normal(ks[next(it)], (cfg.n_tokens, d), s)
    params["t_mlp1"] = _normal(ks[next(it)], (d, d), s)
    params["t_mlp2"] = _normal(ks[next(it)], (d, d), s)
    if cfg.cond_dim:
        params["cond_proj"] = _normal(ks[next(it)], (cfg.cond_dim, d), s)
    if cfg.cond_tokens_dim:
        params["cond_tok_proj"] = _normal(ks[next(it)], (cfg.cond_tokens_dim, d), s)
    for l in range(cfg.n_layers):
        p = f"l{l:02d}."
        params[p + "wq"] = _normal(ks[next(it)], (d, d), s)
        params[p + "wk"] = _normal(ks[next(it)], (d, d), s)
        params[p + "wv"] = _normal(ks[next(it)], (d, d), s)
        params[p + "wo"] = _normal(ks[next(it)], (d, d), s)
        params[p + "w1"] = _normal(ks[next(it)], (d, cfg.d_ff), s)
        params[p + "w2"] = _normal(ks[next(it)], (cfg.d_ff, d), s)
        params[p + "mod_w"] = _normal(ks[next(it)], (d, 6 * d), s)
        params[p + "mod_b"] = jnp.zeros((6 * d,), jnp.float32)
    params["out_ln"] = jnp.ones((d,), jnp.float32)
    params["out_proj"] = _normal(ks[next(it)], (d, cfg.latent_dim), s)
    return params


def encoder_init(cfg: EncoderConfig, seed: int) -> dict:
    key = jax.random.PRNGKey(seed)
    di = cfg.d_inner
    params = {}
    ks = jax.random.split(key, 4 + cfg.n_layers * 8)
    it = iter(range(len(ks)))
    s = 0.02
    params["in_proj"] = _normal(ks[next(it)], (cfg.feat_dim, di), s)
    params["pos"] = _normal(ks[next(it)], (cfg.t_max, di), s)
    for l in range(cfg.n_layers):
        p = f"l{l:02d}."
        params[p + "ln1"] = jnp.ones((di,), jnp.float32)
        params[p + "wq"] = _normal(ks[next(it)], (di, di), s)
        params[p + "wk"] = _normal(ks[next(it)], (di, di), s)
        params[p + "wv"] = _normal(ks[next(it)], (di, di), s)
        params[p + "wo"] = _normal(ks[next(it)], (di, di), s)
        params[p + "ln2"] = jnp.ones((di,), jnp.float32)
        params[p + "w1"] = _normal(ks[next(it)], (di, 4 * di), s)
        params[p + "w2"] = _normal(ks[next(it)], (4 * di, di), s)
    params["out_proj"] = _normal(ks[next(it)], (di, cfg.d_out), s)
    return params


def cnn_vocoder_init(cfg: CnnVocoderConfig, seed: int) -> dict:
    key = jax.random.PRNGKey(seed)
    ch = cfg.channels
    params = {}
    ks = jax.random.split(key, 6)
    s = 0.05
    params["embed"] = _normal(ks[0], (cfg.vocab, cfg.d_embed), s)
    params["in_proj"] = _normal(ks[1], (cfg.d_embed, ch), s)
    params["conv1"] = _normal(ks[2], (5, ch, ch), s)   # [k, in, out]
    params["conv2"] = _normal(ks[3], (5, ch, ch), s)
    params["out_proj"] = _normal(ks[4], (ch, 1), s)
    return params


def patch_codec_init(cfg: PatchCodecConfig, seed: int) -> dict:
    key = jax.random.PRNGKey(seed)
    params = {}
    ks = jax.random.split(key, 6)
    s = 0.02
    params["enc_w1"] = _normal(ks[0], (cfg.patch_dim, cfg.d_model), s)
    params["enc_w2"] = _normal(ks[1], (cfg.d_model, cfg.d_model), s)
    params["dec_embed"] = _normal(ks[2], (cfg.vocab, cfg.d_model), s)
    params["dec_w1"] = _normal(ks[3], (cfg.d_model, cfg.d_model), s)
    params["dec_w2"] = _normal(ks[4], (cfg.d_model, cfg.samples_per_patch), s)
    return params


# ---------------------------------------------------------------------------
# KV-cache plumbing.  Cache layout: [L, 2, B, H, S, dh] (single tensor so
# the Rust side marshals one buffer per call).
# ---------------------------------------------------------------------------

def kv_shape(cfg: ArConfig, batch: int):
    return (cfg.n_layers, 2, batch, cfg.n_heads, cfg.max_seq, cfg.d_head)


def kv_write_rows(kv_l, new_k, new_v, start):
    """Write rows into one layer's cache at per-sequence offsets.

    kv_l: [2, B, H, S, dh]; new_k/new_v: [B, H, C, dh]; start: [B] int32.
    Returns updated [2, B, H, S, dh].
    """
    def upd(cache_b, rows_b, pos):
        # cache_b: [H, S, dh], rows_b: [H, C, dh]
        return jax.lax.dynamic_update_slice(cache_b, rows_b, (0, pos, 0))

    k_upd = jax.vmap(upd)(kv_l[0], new_k, start)
    v_upd = jax.vmap(upd)(kv_l[1], new_v, start)
    return jnp.stack([k_upd, v_upd])
