"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must match its oracle here under `numpy.testing.assert_allclose` across the
shape/dtype sweep in ``python/tests/test_kernels.py`` (hypothesis-driven).

The oracles are deliberately written in the most direct way possible (no
blocking, no online softmax, no fused modulation) so that a bug in the
kernel cannot be mirrored in the reference.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, lengths):
    """Single-query (decode-step) attention over a padded KV cache.

    Args:
      q:       [B, H, dh]  query for the current decode position.
      k, v:    [B, H, S, dh]  padded KV cache (rows >= lengths[b] are junk).
      lengths: [B] int32  number of valid cache rows per sequence.

    Returns:
      [B, H, dh] attention output.  Sequences with length == 0 return 0.
    """
    b, h, s, dh = k.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))
    scores = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    pos = jnp.arange(s, dtype=jnp.int32)[None, None, :]
    valid = pos < lengths[:, None, None]
    scores = jnp.where(valid, scores, NEG_INF)
    # A fully-masked row would produce NaN through softmax; force it to 0.
    any_valid = jnp.any(valid, axis=-1, keepdims=True)
    probs = jnp.where(
        any_valid,
        jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True)),
        0.0,
    )
    denom = jnp.sum(probs, axis=-1, keepdims=True)
    probs = probs / jnp.where(denom == 0.0, 1.0, denom)
    out = jnp.einsum("bhs,bhsd->bhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def prefix_chunk_attention_ref(q, k, v, base):
    """Chunked-prefill attention oracle.

    Args:
      q:    [B, H, C, dh]  queries for chunk rows (absolute pos = base+t).
      k, v: [B, H, S, dh]  padded cache holding prefix AND the chunk rows.
      base: [B] int32  absolute position of the first chunk row.

    Row t of the chunk may attend to cache rows [0, base+t] (causal within
    the chunk, full visibility of the prefix).
    """
    bsz, h, c, dh = q.shape
    s = k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))
    scores = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    pos = jnp.arange(s, dtype=jnp.int32)[None, None, None, :]  # [1,1,1,S]
    row = jnp.arange(c, dtype=jnp.int32)[None, None, :, None]  # [1,1,C,1]
    limit = base[:, None, None, None] + row  # inclusive upper bound
    valid = pos <= limit
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    denom = jnp.sum(probs, axis=-1, keepdims=True)
    probs = probs / jnp.where(denom == 0.0, 1.0, denom)
    out = jnp.einsum("bhts,bhsd->bhtd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def adaln_block_ref(x, t_emb, wq, wk, wv, wo, w1, w2, mod_w, mod_b, n_heads=4):
    """DiT block oracle: AdaLN-Zero modulation + self-attention + MLP.

    Args:
      x:     [B, N, D]   token latents.
      t_emb: [B, D]      timestep/conditioning embedding.
      wq/wk/wv/wo: [D, D] attention projections (no bias).
      w1: [D, F], w2: [F, D] MLP projections.
      mod_w: [D, 6*D], mod_b: [6*D]  modulation producing
             (shift_a, scale_a, gate_a, shift_m, scale_m, gate_m).

    Returns [B, N, D].
    """
    b, n, d = x.shape
    h = n_heads
    dh = d // h
    x = x.astype(jnp.float32)
    t_emb = t_emb.astype(jnp.float32)
    mod = jnp.dot(t_emb, mod_w.astype(jnp.float32)) + mod_b.astype(jnp.float32)
    sa, ca, ga, sm, cm, gm = jnp.split(mod, 6, axis=-1)

    def layernorm(y):
        mu = jnp.mean(y, axis=-1, keepdims=True)
        var = jnp.var(y, axis=-1, keepdims=True)
        return (y - mu) / jnp.sqrt(var + 1e-6)

    def gelu(y):
        return 0.5 * y * (1.0 + jnp.tanh(jnp.sqrt(2.0 / jnp.pi) * (y + 0.044715 * y**3)))

    # --- attention branch ---
    xn = layernorm(x) * (1.0 + ca[:, None, :]) + sa[:, None, :]
    q = jnp.einsum("bnd,de->bne", xn, wq).reshape(b, n, h, dh).transpose(0, 2, 1, 3)
    k = jnp.einsum("bnd,de->bne", xn, wk).reshape(b, n, h, dh).transpose(0, 2, 1, 3)
    v = jnp.einsum("bnd,de->bne", xn, wv).reshape(b, n, h, dh).transpose(0, 2, 1, 3)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))
    att = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
    att = jnp.exp(att - jnp.max(att, axis=-1, keepdims=True))
    att = att / jnp.sum(att, axis=-1, keepdims=True)
    o = jnp.einsum("bhts,bhsd->bhtd", att, v).transpose(0, 2, 1, 3).reshape(b, n, d)
    x = x + ga[:, None, :] * jnp.einsum("bnd,de->bne", o, wo)

    # --- MLP branch ---
    xn = layernorm(x) * (1.0 + cm[:, None, :]) + sm[:, None, :]
    hdn = gelu(jnp.einsum("bnd,df->bnf", xn, w1))
    x = x + gm[:, None, :] * jnp.einsum("bnf,fd->bnd", hdn, w2)
    return x
