"""L1 Pallas kernels: decode-step and chunked-prefill attention.

TPU-shaped design (see DESIGN.md §Hardware-Adaptation): the paper's serving
engines lean on CUDA flash-attention; here the same IO-awareness insight is
expressed for the TPU memory hierarchy.  The KV cache lives in "HBM" and is
staged into VMEM per (batch, head) program via BlockSpec; within a program
we run an online-softmax sweep over KV blocks so the full [S] score row is
never materialized.

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls, and the interpret path lowers to plain
HLO that the Rust runtime replays.  VMEM budgeting (the real-TPU argument)
is documented in DESIGN.md §Perf:

  decode kernel, per program: K tile [S, dh] + V tile [S, dh]
    = 2 * 256 * 64 * 4 B = 128 KiB  « 16 MiB VMEM
  chunk kernel adds Q [C, dh] (C=32): + 8 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
DEFAULT_KV_BLOCK = 64


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, kv_block: int):
    """One program handles one (batch, head) pair.

    q_ref: [dh]; k_ref/v_ref: [S, dh]; len_ref: scalar prefetch-ish [1];
    o_ref: [dh].
    """
    s, dh = k_ref.shape
    length = len_ref[0]
    q = q_ref[...].astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))

    n_blocks = s // kv_block

    def body(i, carry):
        m_prev, l_prev, acc = carry
        start = i * kv_block
        k_blk = k_ref[pl.dslice(start, kv_block), :].astype(jnp.float32)
        v_blk = v_ref[pl.dslice(start, kv_block), :].astype(jnp.float32)
        scores = jnp.dot(k_blk, q) * scale  # [kv_block]
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (kv_block,), 0)
        scores = jnp.where(pos < length, scores, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(scores))
        alpha = jnp.exp(m_prev - m_cur)
        # Guard the all-masked case: exp(NEG_INF - NEG_INF) would be 1.
        p = jnp.where(scores > NEG_INF * 0.5, jnp.exp(scores - m_cur), 0.0)
        l_cur = l_prev * alpha + jnp.sum(p)
        acc = acc * alpha + jnp.dot(p, v_blk)
        return m_cur, l_cur, acc

    m0 = jnp.asarray(NEG_INF, dtype=jnp.float32)
    l0 = jnp.asarray(0.0, dtype=jnp.float32)
    acc0 = jnp.zeros((dh,), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    out = acc / jnp.where(l == 0.0, 1.0, l)
    o_ref[...] = out.astype(o_ref.dtype)


def decode_attention(q, k, v, lengths, *, kv_block: int = DEFAULT_KV_BLOCK, interpret: bool = True):
    """Flash decode attention.  Shapes as in ``ref.decode_attention_ref``.

    q: [B, H, dh], k/v: [B, H, S, dh], lengths: [B] int32 -> [B, H, dh].
    """
    b, h, s, dh = k.shape
    assert q.shape == (b, h, dh), (q.shape, k.shape)
    kv_block = min(kv_block, s)
    assert s % kv_block == 0, f"S={s} must be a multiple of kv_block={kv_block}"
    kernel = functools.partial(_decode_kernel, kv_block=kv_block)
    grid = (b, h)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),          # lengths[b]
            pl.BlockSpec((None, None, dh), lambda i, j: (i, j, 0)),  # q[b,h]
            pl.BlockSpec((None, None, s, dh), lambda i, j: (i, j, 0, 0)),  # k[b,h]
            pl.BlockSpec((None, None, s, dh), lambda i, j: (i, j, 0, 0)),  # v[b,h]
        ],
        out_specs=pl.BlockSpec((None, None, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)


def _chunk_kernel(base_ref, q_ref, k_ref, v_ref, o_ref, *, kv_block: int):
    """Chunked-prefill attention for one (batch, head) pair.

    q_ref: [C, dh]; k_ref/v_ref: [S, dh]; base_ref: [1];
    o_ref: [C, dh].  Row t attends to cache rows <= base + t.
    """
    s, dh = k_ref.shape
    c = q_ref.shape[0]
    base = base_ref[0]
    q = q_ref[...].astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))
    n_blocks = s // kv_block
    rows = jax.lax.broadcasted_iota(jnp.int32, (c, 1), 0)  # [C,1]

    def body(i, carry):
        m_prev, l_prev, acc = carry  # [C,1], [C,1], [C,dh]
        start = i * kv_block
        k_blk = k_ref[pl.dslice(start, kv_block), :].astype(jnp.float32)
        v_blk = v_ref[pl.dslice(start, kv_block), :].astype(jnp.float32)
        scores = jnp.dot(q, k_blk.T) * scale  # [C, kv_block]
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (1, kv_block), 1)
        valid = pos <= (base + rows)  # [C, kv_block]
        scores = jnp.where(valid, scores, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        # Guard fully-masked rows: exp(NEG_INF - NEG_INF) would be 1.
        p = jnp.where(scores > NEG_INF * 0.5, jnp.exp(scores - m_cur), 0.0)
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.dot(p, v_blk)
        return m_cur, l_cur, acc

    m0 = jnp.full((c, 1), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((c, 1), dtype=jnp.float32)
    acc0 = jnp.zeros((c, dh), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    out = acc / jnp.where(l == 0.0, 1.0, l)
    o_ref[...] = out.astype(o_ref.dtype)


def prefix_chunk_attention(q, k, v, base, *, kv_block: int = DEFAULT_KV_BLOCK, interpret: bool = True):
    """Chunked-prefill flash attention.  Shapes as in
    ``ref.prefix_chunk_attention_ref``.

    q: [B, H, C, dh], k/v: [B, H, S, dh], base: [B] int32 -> [B, H, C, dh].
    """
    b, h, c, dh = q.shape
    s = k.shape[2]
    kv_block = min(kv_block, s)
    assert s % kv_block == 0, f"S={s} must be a multiple of kv_block={kv_block}"
    kernel = functools.partial(_chunk_kernel, kv_block=kv_block)
    grid = (b, h)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((None, None, c, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, s, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, s, dh), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, c, dh), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, c, dh), q.dtype),
        interpret=interpret,
    )(base, q, k, v)
