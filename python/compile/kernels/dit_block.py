"""L1 Pallas kernel: fused AdaLN-Zero DiT block.

The paper's diffusion engine cites fused/quantized attention backends
(flash-attention, SAGE, TurboAttention) as the per-step hot path of DiT
serving.  On TPU the equivalent structural win is fusing the whole
modulate -> attention -> gate -> modulate -> MLP -> gate block into one
kernel so the [N, D] activations make a single HBM->VMEM round trip per
block instead of ~10 (one per elementwise/matmul op).

One program per batch element.  VMEM budget per program (N=512, D=320,
F=4D): x [N,D] 640 KiB + qkv 3x640 KiB + attn row-block + MLP tile
~= 4.5 MiB « 16 MiB.  MXU alignment: D and F are multiples of 64/128 for
all shipped configs (256/320/384), N is a multiple of 128.

Lowered with interpret=True (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gelu(y):
    return 0.5 * y * (1.0 + jnp.tanh(jnp.sqrt(2.0 / jnp.pi) * (y + 0.044715 * y**3)))


def _layernorm(y, eps=1e-6):
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    return (y - mu) / jnp.sqrt(var + eps)


def _adaln_kernel(
    x_ref, t_ref, wq_ref, wk_ref, wv_ref, wo_ref, w1_ref, w2_ref, modw_ref, modb_ref, o_ref,
    *, n_heads: int,
):
    """x_ref: [N, D]; t_ref: [D]; weight refs as in adaln_block_ref; o_ref [N, D]."""
    n, d = x_ref.shape
    h = n_heads
    dh = d // h
    x = x_ref[...].astype(jnp.float32)
    t_emb = t_ref[...].astype(jnp.float32)

    mod = jnp.dot(t_emb, modw_ref[...].astype(jnp.float32)) + modb_ref[...].astype(jnp.float32)
    sa, ca, ga, sm, cm, gm = [mod[i * d:(i + 1) * d] for i in range(6)]

    # --- attention branch, all heads materialized in VMEM ---
    xn = _layernorm(x) * (1.0 + ca) + sa
    q = jnp.dot(xn, wq_ref[...].astype(jnp.float32)).reshape(n, h, dh)
    k = jnp.dot(xn, wk_ref[...].astype(jnp.float32)).reshape(n, h, dh)
    v = jnp.dot(xn, wv_ref[...].astype(jnp.float32)).reshape(n, h, dh)
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=jnp.float32))

    # [h, n, dh] layout for the MXU matmuls
    qh = q.transpose(1, 0, 2)
    kh = k.transpose(1, 0, 2)
    vh = v.transpose(1, 0, 2)
    att = jnp.einsum("htd,hsd->hts", qh, kh) * scale
    att = att - jnp.max(att, axis=-1, keepdims=True)
    att = jnp.exp(att)
    att = att / jnp.sum(att, axis=-1, keepdims=True)
    o = jnp.einsum("hts,hsd->htd", att, vh).transpose(1, 0, 2).reshape(n, d)
    x = x + ga * jnp.dot(o, wo_ref[...].astype(jnp.float32))

    # --- MLP branch ---
    xn = _layernorm(x) * (1.0 + cm) + sm
    hdn = _gelu(jnp.dot(xn, w1_ref[...].astype(jnp.float32)))
    x = x + gm * jnp.dot(hdn, w2_ref[...].astype(jnp.float32))
    o_ref[...] = x.astype(o_ref.dtype)


def adaln_block(x, t_emb, wq, wk, wv, wo, w1, w2, mod_w, mod_b, *, n_heads: int = 4, interpret: bool = True):
    """Fused AdaLN-Zero DiT block.  Shapes as in ``ref.adaln_block_ref``.

    x: [B, N, D], t_emb: [B, D] -> [B, N, D].
    """
    b, n, d = x.shape
    f = w1.shape[1]
    assert d % n_heads == 0
    kernel = functools.partial(_adaln_kernel, n_heads=n_heads)
    full = lambda *dims: pl.BlockSpec(dims, lambda i: tuple(0 for _ in dims))
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((None, n, d), lambda i: (i, 0, 0)),  # x[b]
            pl.BlockSpec((None, d), lambda i: (i, 0)),        # t_emb[b]
            full(d, d), full(d, d), full(d, d), full(d, d),   # wq wk wv wo
            full(d, f), full(f, d),                           # w1 w2
            full(d, 6 * d),                                   # mod_w
            full(6 * d),                                      # mod_b
        ],
        out_specs=pl.BlockSpec((None, n, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n, d), x.dtype),
        interpret=interpret,
    )(x, t_emb, wq, wk, wv, wo, w1, w2, mod_w, mod_b)
